//! The batch scheduler: N jobs in flight under one global worker budget.
//!
//! Two nested levels of parallelism share a single pool of
//! `BatchOptions::threads` workers:
//!
//! * **across jobs** — up to `job_threads` jobs run concurrently;
//! * **within a job** — each job leases workers from the shared
//!   [`ThreadBudget`] and runs its skeleton pipeline at the leased
//!   width ([`crate::skeleton::Config::with_threads`]).
//!
//! The lease policy is work-conserving *and elastic*: a job asks for its
//! fair share of the remaining jobs (so seven small jobs split the
//! budget) and then **re-leases between skeleton levels and at the
//! orientation boundary** through an [`ElasticLease`] wired into the
//! job's [`crate::skeleton::WidthPolicy`] hook — the lease is held
//! until the job's CPDAG is finished, so the parallel orientation
//! pipeline (v-structures, majority census, Meek sweeps) runs at the
//! re-leased width too. A boundary re-lease targets
//! the job's *current fair share*: it absorbs every idle worker while
//! nothing is queued (a long tail level borrows what finished jobs
//! returned) and shrinks back when leasers are waiting (waking them) —
//! so a wide job yields at the next level boundary rather than starving
//! the queue. Growth is non-blocking and takes only idle workers, so a
//! re-lease can never stall a running job.
//!
//! Caching is two-tier: every job consults the in-process
//! [`Cache`] first and, when a [`DiskStore`] is configured
//! (`--cache-dir`), falls back to the persistent store before
//! recomputing — so repeated `cupc batch` invocations share warm
//! correlation matrices and results across processes.
//!
//! Determinism: the lease size (including any mid-job resize), the
//! number of job workers, and the cache state — memory or disk — can
//! only change wall-clock time. Per-job results are width-invariant
//! (the pipeline contract), the correlation gram is blocked identically
//! for any width, cache values are exactly the bytes a cold computation
//! produces (the disk store checksums them), and reports are collected
//! by manifest index — so the rendered results stream is bit-identical
//! for any `job_threads`, any budget, any re-lease schedule, and
//! cold/warm/disk cache (`tests/batch_runner.rs` gates all of it).

use super::cache::{self, Cache, CacheStats};
use super::job::{DataSource, JobSpec, Manifest};
use super::report::{CacheOutcome, JobReport, JobResultCore};
use super::store::{DiskStats, DiskStore};
use crate::api::pc_stable_corr;
use crate::data::csv::load_csv;
use crate::sim::{datasets, scenarios};
use crate::skeleton::{available_threads, WidthHook, WidthPolicy};
use crate::stats::corr::DataMatrix;
use crate::util::timer::Timer;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A counting budget of pipeline workers shared by every in-flight job.
pub struct ThreadBudget {
    state: Mutex<BudgetState>,
    cv: Condvar,
    total: usize,
}

struct BudgetState {
    available: usize,
    /// callers currently inside `acquire` (for fair division)
    waiters: usize,
}

impl ThreadBudget {
    pub fn new(total: usize) -> Self {
        let total = total.max(1);
        ThreadBudget {
            state: Mutex::new(BudgetState {
                available: total,
                waiters: 0,
            }),
            cv: Condvar::new(),
            total,
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Workers currently idle (observational — feeds the serve daemon's
    /// `/stats` endpoint; racy by nature, never used for scheduling).
    pub fn idle(&self) -> usize {
        self.state.lock().unwrap().available
    }

    /// The blocking grant at the heart of [`ThreadBudget::lease`]:
    /// between 1 and `want` workers, capped at the fair share of what is
    /// idle among concurrent leasers.
    fn acquire(&self, want: usize) -> usize {
        let want = want.max(1);
        let mut st = self.state.lock().unwrap();
        st.waiters += 1;
        while st.available == 0 {
            st = self.cv.wait(st).unwrap();
        }
        let fair = (st.available / st.waiters).max(1);
        let n = fair.min(want).min(st.available);
        st.available -= n;
        st.waiters -= 1;
        n
    }

    /// Lease between 1 and `want` workers, blocking while none are
    /// available. The grant is capped at the fair share of what is idle
    /// among concurrent leasers, so simultaneous arrivals split the
    /// budget instead of the first one draining it.
    pub fn lease(&self, want: usize) -> Lease<'_> {
        Lease {
            budget: self,
            n: self.acquire(want),
        }
    }

    /// Re-lease `held` workers toward `target`, returning the new held
    /// count. Shrinking returns workers to the budget immediately (and
    /// wakes blocked leasers); growing is **non-blocking** — it takes
    /// only idle workers, and only a fair share of them when other
    /// leasers are waiting, so a resize can never stall a running job or
    /// starve a queued one.
    fn resize(&self, held: usize, target: usize) -> usize {
        let target = target.max(1);
        if target == held {
            return held;
        }
        let mut st = self.state.lock().unwrap();
        let n = if target < held {
            st.available += held - target;
            target
        } else {
            let room = target - held;
            let grantable = if st.waiters == 0 {
                st.available
            } else {
                st.available / (st.waiters + 1)
            };
            let extra = grantable.min(room);
            st.available -= extra;
            held + extra
        };
        drop(st);
        if n < held {
            self.cv.notify_all();
        }
        n
    }

    /// The work-conserving re-lease target for a holder of `held`
    /// workers: every idle worker when nobody is waiting, else an equal
    /// split of `held + idle` between the holder and the waiters — so a
    /// boundary re-lease *shrinks* a wide lease when jobs queue up
    /// behind it (the release wakes them) instead of starving them
    /// until the wide job finishes.
    fn fair_share_target(&self, held: usize) -> usize {
        let st = self.state.lock().unwrap();
        if st.waiters == 0 {
            held + st.available
        } else {
            ((held + st.available) / (st.waiters + 1)).max(1)
        }
    }

    fn release(&self, n: usize) {
        let mut st = self.state.lock().unwrap();
        st.available += n;
        drop(st);
        self.cv.notify_all();
    }
}

/// A held worker allocation; returns the workers on drop.
pub struct Lease<'a> {
    budget: &'a ThreadBudget,
    /// number of workers granted (≥ 1)
    pub n: usize,
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        self.budget.release(self.n);
    }
}

/// An owned, shareable elastic lease: the initial grant blocks like
/// [`ThreadBudget::lease`]; afterwards the lease doubles as the job's
/// [`WidthPolicy`] — before each skeleton level it re-leases toward its
/// current fair share: absorbing every idle worker while the queue is
/// quiet, and shrinking back (waking the blocked leasers) when jobs are
/// waiting, so a wide job yields at the next level boundary instead of
/// starving the queue. Dropping the lease releases the held workers.
pub struct ElasticLease {
    budget: Arc<ThreadBudget>,
    /// (held, peak) — peak feeds the stats sidecar
    state: Mutex<(usize, usize)>,
}

impl ElasticLease {
    /// Blockingly lease up to `want` workers from `budget`.
    pub fn acquire(budget: Arc<ThreadBudget>, want: usize) -> Arc<ElasticLease> {
        let n = budget.acquire(want);
        Arc::new(ElasticLease {
            budget,
            state: Mutex::new((n, n)),
        })
    }

    /// Workers currently held.
    pub fn width(&self) -> usize {
        self.state.lock().unwrap().0
    }

    /// Widest this lease has ever been (observational, for the stats
    /// sidecar).
    pub fn peak(&self) -> usize {
        self.state.lock().unwrap().1
    }

    /// Re-lease toward `target`; returns the new width. Shrink returns
    /// workers to the budget immediately (waking blocked leasers);
    /// growth is non-blocking and takes only idle workers.
    pub fn resize(&self, target: usize) -> usize {
        let mut st = self.state.lock().unwrap();
        st.0 = self.budget.resize(st.0, target);
        st.1 = st.1.max(st.0);
        st.0
    }

    /// This lease as a between-level width hook for
    /// [`crate::skeleton::Config`].
    pub fn hook(lease: &Arc<ElasticLease>) -> WidthHook {
        WidthHook(lease.clone())
    }
}

impl WidthPolicy for ElasticLease {
    fn width_for_level(&self, _level: usize) -> usize {
        // between levels: absorb every idle worker when the machine is
        // quiet, and *give workers back* when jobs are queued — the
        // fair-share target shrinks a wide lease so a long job can
        // never starve later arrivals for its whole runtime
        let target = self.budget.fair_share_target(self.width());
        self.resize(target)
    }
}

impl Drop for ElasticLease {
    fn drop(&mut self) {
        let held = self.state.get_mut().unwrap().0;
        self.budget.release(held);
    }
}

/// Batch-run knobs.
#[derive(Clone, Debug)]
pub struct BatchOptions {
    /// jobs in flight at once
    pub job_threads: usize,
    /// global pipeline-worker budget shared by all in-flight jobs
    pub threads: usize,
    /// in-process cache byte budget
    pub cache_bytes: usize,
    /// persistent cache directory shared across invocations/processes
    /// (`--cache-dir`); `None` keeps caching in-process only
    pub cache_dir: Option<PathBuf>,
    /// byte budget for the persistent store (`--cache-disk-mb`)
    pub disk_bytes: u64,
    /// per-job progress on stderr
    pub verbose: bool,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            job_threads: 1,
            threads: available_threads(),
            cache_bytes: 256 << 20,
            cache_dir: None,
            disk_bytes: 1 << 30,
            verbose: false,
        }
    }
}

/// Everything a batch run produces, reports in manifest order.
pub struct BatchOutput {
    pub reports: Vec<JobReport>,
    pub cache: CacheStats,
    /// persistent-store counters (`None` without `--cache-dir`)
    pub disk: Option<DiskStats>,
}

/// Resolve a job's data source (CSV read / dataset generator / scenario
/// grid point). Shared with the `cupc shard` coordinator, which computes
/// the correlation matrix itself instead of going through [`run_job`].
pub fn load_data(spec: &JobSpec) -> Result<DataMatrix> {
    match &spec.source {
        DataSource::Csv(p) => Ok(load_csv(p)?.0),
        DataSource::Dataset(name) => {
            let s = datasets::spec(name).with_context(|| format!("unknown dataset {name:?}"))?;
            Ok(datasets::generate(s).data)
        }
        DataSource::Scenario(name) => {
            let sc = scenarios::find(name).with_context(|| format!("unknown scenario {name:?}"))?;
            Ok(sc.generate_data().1)
        }
    }
}

/// Run one job on an elastic worker lease against the shared in-process
/// cache, with an optional persistent second tier. Lookup order per
/// layer: memory, then disk (both content-addressed on the same key),
/// then recompute — a recompute populates both tiers.
pub fn run_job(
    spec: &JobSpec,
    lease: &Arc<ElasticLease>,
    cache: &Cache,
    store: Option<&DiskStore>,
) -> Result<JobReport> {
    let t = Timer::start();
    let data = load_data(spec).with_context(|| format!("job {:?}", spec.name))?;
    let seconds_load = t.elapsed_s();
    let threads_start = lease.width();

    // causal-order families skip the correlation layer entirely (the
    // engine consumes raw columns) but share the result layer, the
    // elastic lease, and both cache tiers byte for byte with PC jobs —
    // the registry kind is the only dispatch point
    if let crate::family::FamilyKind::Order(run) = crate::family::of(spec.family).kind {
        let t = Timer::start();
        let rk = cache::result_key(
            &data.x,
            data.n,
            data.m,
            spec.alpha,
            spec.max_level,
            spec.family,
            spec.orient,
        );
        let (core, result_cache) = loop {
            if let Some(c) = cache.get_result(rk) {
                break (c, CacheOutcome::Mem);
            }
            if let Some(claim) = cache.claim_compute(rk) {
                if let Some(loaded) = store.and_then(|s| s.get_result(rk)) {
                    let core = Arc::new(loaded);
                    cache.put_result(rk, core.clone());
                    drop(claim);
                    break (core, CacheOutcome::Disk);
                }
                let mut cfg = spec.config(lease.width());
                // re-lease between root-finding rounds, like PC levels
                cfg.width_hook = Some(ElasticLease::hook(lease));
                let res = run(&data, &cfg)
                    .map(|r| Arc::new(JobResultCore::from_order(&r, data.n, data.m)));
                if let Ok(core) = &res {
                    cache.put_result(rk, core.clone());
                }
                drop(claim);
                let core = res
                    .with_context(|| format!("job {:?} ({})", spec.name, spec.source.label()))?;
                if let Some(s) = store {
                    s.put_result(rk, &core);
                }
                break (core, CacheOutcome::Miss);
            }
        };
        return Ok(JobReport {
            core,
            seconds_load,
            seconds_corr: 0.0,
            seconds_run: t.elapsed_s(),
            corr_cache: CacheOutcome::Miss,
            result_cache,
            threads_used: threads_start,
            threads_peak: lease.peak(),
            adjacency: "dense",
            peak_window_bytes: 0,
        });
    }

    let t = Timer::start();
    let dk = cache::data_key(&data, spec.corr);
    let (corr, corr_cache) = loop {
        if let Some(c) = cache.get_corr(dk) {
            break (c, CacheOutcome::Mem);
        }
        // coalesce concurrent jobs over the same data: one computes (or
        // loads) the gram, the others wait on the claim and re-check the
        // cache. The disk probe sits inside the claim so concurrent
        // same-data jobs do one read, not N.
        if let Some(claim) = cache.claim_compute(dk) {
            if let Some(v) = store.and_then(|s| s.get_corr(dk, data.n * data.n)) {
                let c = Arc::new(v);
                cache.put_corr(dk, c.clone());
                drop(claim);
                break (c, CacheOutcome::Disk);
            }
            let c = Arc::new(spec.corr.matrix(&data, lease.width()));
            cache.put_corr(dk, c.clone());
            // waiters only need the memory value — release them before
            // the (fsync-priced, best-effort) disk write
            drop(claim);
            if let Some(s) = store {
                s.put_corr(dk, &c);
            }
            break (c, CacheOutcome::Miss);
        }
    };
    let seconds_corr = t.elapsed_s();

    let t = Timer::start();
    let rk = cache::result_key(
        &corr,
        data.n,
        data.m,
        spec.alpha,
        spec.max_level,
        spec.family,
        spec.orient,
    );
    // out-of-core observability for the stats sidecar; stays at the
    // defaults ("dense", 0) when the result is served from a cache tier
    // (no skeleton ran) — deliberately NOT cached alongside the result
    // core, which carries deterministic fields only
    let mut ooc = crate::skeleton::OocStats::default();
    let (core, result_cache) = loop {
        if let Some(c) = cache.get_result(rk) {
            break (c, CacheOutcome::Mem);
        }
        if let Some(claim) = cache.claim_compute(rk) {
            if let Some(loaded) = store.and_then(|s| s.get_result(rk)) {
                let core = Arc::new(loaded);
                cache.put_result(rk, core.clone());
                drop(claim);
                break (core, CacheOutcome::Disk);
            }
            let mut cfg = spec.config(lease.width());
            // the job re-leases through this hook between skeleton
            // levels (batched schedules only — a serial/parcpu skeleton
            // keeps its starting width) and, for EVERY variant, once
            // more at the orientation boundary: the lease stays alive
            // through orientation, so a census-heavy job absorbs idle
            // workers for its v-structure/Meek phase too
            cfg.width_hook = Some(ElasticLease::hook(lease));
            let res = pc_stable_corr(&corr, data.n, data.m, &cfg).map(|r| {
                ooc = r.skeleton.ooc;
                Arc::new(JobResultCore::from_pc(&r, data.n, data.m))
            });
            if let Ok(core) = &res {
                cache.put_result(rk, core.clone());
            }
            // release before `?` so a failure never strands waiters, and
            // before the disk write so they aren't stalled by the fsync
            drop(claim);
            let core = res
                .with_context(|| format!("job {:?} ({})", spec.name, spec.source.label()))?;
            if let Some(s) = store {
                s.put_result(rk, &core);
            }
            break (core, CacheOutcome::Miss);
        }
    };
    let seconds_run = t.elapsed_s();

    Ok(JobReport {
        core,
        seconds_load,
        seconds_corr,
        seconds_run,
        corr_cache,
        result_cache,
        threads_used: threads_start,
        threads_peak: lease.peak(),
        adjacency: ooc.adjacency,
        peak_window_bytes: ooc.peak_window_bytes,
    })
}

/// Run every manifest job, up to `job_threads` concurrently, under one
/// shared [`ThreadBudget`] and [`Cache`] (plus the persistent store when
/// `opts.cache_dir` is set). Reports come back in manifest order. On a
/// job failure the batch stops claiming new jobs (jobs already in
/// flight run to completion) and the lowest-index error is reported.
///
/// An unusable `cache_dir` (uncreatable/read-only) fails the batch up
/// front — deliberately stricter than the store's per-entry
/// corruption-is-a-miss policy: the user asked for persistence by name,
/// and silently downgrading to in-process caching would hide that every
/// future invocation will run cold.
pub fn run_batch(manifest: &Manifest, opts: &BatchOptions, cache: &Cache) -> Result<BatchOutput> {
    let store = match &opts.cache_dir {
        Some(dir) => Some(DiskStore::open(dir, opts.disk_bytes)?),
        None => None,
    };
    let store = store.as_ref();
    let njobs = manifest.jobs.len();
    let workers = opts.job_threads.clamp(1, njobs.max(1));
    let budget = Arc::new(ThreadBudget::new(opts.threads));
    let mut slots: Vec<Option<Result<JobReport>>> = Vec::with_capacity(njobs);
    slots.resize_with(njobs, || None);

    if workers <= 1 {
        for (idx, spec) in manifest.jobs.iter().enumerate() {
            let lease = ElasticLease::acquire(budget.clone(), budget.total());
            if opts.verbose {
                eprintln!(
                    "[batch] job {idx} {:?}: {} worker(s)",
                    spec.name,
                    lease.width()
                );
            }
            let rep = run_job(spec, &lease, cache, store);
            let failed = rep.is_err();
            slots[idx] = Some(rep);
            if failed {
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        let aborted = AtomicBool::new(false);
        let results = Mutex::new(slots);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    if aborted.load(Ordering::Relaxed) {
                        break;
                    }
                    let idx = next.fetch_add(1, Ordering::Relaxed);
                    if idx >= njobs {
                        break;
                    }
                    let spec = &manifest.jobs[idx];
                    // fair share of the queue that is left; the last
                    // jobs standing borrow the drained queue's workers
                    // (and re-lease the rest between levels)
                    let remaining = njobs - idx;
                    let want = (budget.total() / workers.min(remaining)).max(1);
                    let lease = ElasticLease::acquire(budget.clone(), want);
                    if opts.verbose {
                        eprintln!(
                            "[batch] job {idx} {:?}: {} worker(s)",
                            spec.name,
                            lease.width()
                        );
                    }
                    let rep = run_job(spec, &lease, cache, store);
                    drop(lease);
                    if rep.is_err() {
                        aborted.store(true, Ordering::Relaxed);
                    }
                    results.lock().unwrap()[idx] = Some(rep);
                });
            }
        });
        slots = results.into_inner().unwrap();
    }

    let mut reports = Vec::with_capacity(njobs);
    for (idx, slot) in slots.into_iter().enumerate() {
        // claims are handed out in index order, so a failure (Some(Err))
        // always precedes the skipped suffix (None) — the real error is
        // what surfaces
        let rep = slot
            .with_context(|| format!("job #{idx} skipped after an earlier job failed"))?
            .with_context(|| format!("job #{idx} failed"))?;
        reports.push(rep);
    }
    Ok(BatchOutput {
        reports,
        cache: cache.stats(),
        disk: store.map(|s| s.stats()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::report::render_results;
    use crate::family::FamilyId;
    use crate::skeleton::{OrientRule, Variant};
    use crate::stats::corr::CorrKind;

    fn scenario_job(name: &str, scenario: &str, alpha: f64, corr: CorrKind) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            source: DataSource::Scenario(scenario.to_string()),
            family: FamilyId::Pc(Variant::CupcS),
            alpha,
            max_level: None,
            corr,
            orient: OrientRule::Standard,
        }
    }

    /// A lone elastic lease over a private budget — the test analog of
    /// the old fixed-width `run_job(spec, threads, cache)` call.
    fn lone_lease(threads: usize) -> Arc<ElasticLease> {
        let budget = Arc::new(ThreadBudget::new(threads));
        ElasticLease::acquire(budget, threads)
    }

    #[test]
    fn budget_grants_are_bounded_and_returned() {
        let b = ThreadBudget::new(8);
        assert_eq!(b.total(), 8);
        {
            let lone = b.lease(100);
            assert_eq!(lone.n, 8, "a lone leaser borrows the whole budget");
        }
        let small = b.lease(3);
        assert_eq!(small.n, 3, "want caps the grant");
        let rest = b.lease(100);
        assert_eq!(rest.n, 5, "only the idle workers are grantable");
        drop(small);
        drop(rest);
        assert_eq!(b.lease(100).n, 8, "drops return every worker");
    }

    #[test]
    fn zero_budget_still_grants_one() {
        let b = ThreadBudget::new(0);
        assert_eq!(b.total(), 1, "a budget can never be empty");
        assert_eq!(b.lease(1).n, 1);
    }

    /// The raw grow/shrink accounting behind [`ElasticLease::resize`]
    /// (driven directly so every arithmetic branch is pinned without a
    /// second public lease type).
    #[test]
    fn budget_resize_grows_from_idle_and_shrinks_immediately() {
        let b = ThreadBudget::new(8);
        let mut a = b.acquire(4);
        let c = b.acquire(2);
        assert_eq!((a, c), (4, 2));
        a = b.resize(a, 8);
        assert_eq!(a, 6, "growth takes only the 2 idle workers");
        b.release(c);
        a = b.resize(a, 8);
        assert_eq!(a, 8, "freed workers are absorbed");
        assert_eq!(b.resize(a, 8), 8, "resize to the current width is a no-op");
        a = b.resize(a, 2);
        assert_eq!(a, 2, "shrink releases immediately");
        assert_eq!(b.lease(100).n, 6, "shrunk workers are leasable again");
        b.release(a);
        assert_eq!(b.lease(100).n, 8, "a resized holding releases its final width");
    }

    #[test]
    fn elastic_lease_absorbs_freed_workers_between_levels() {
        let b = Arc::new(ThreadBudget::new(4));
        let lease = ElasticLease::acquire(b.clone(), 2);
        assert_eq!(lease.width(), 2);
        let other = b.lease(2);
        assert_eq!(
            lease.width_for_level(1),
            2,
            "nothing idle: the level runs at the held width"
        );
        drop(other);
        assert_eq!(
            lease.width_for_level(2),
            4,
            "a freed budget is absorbed at the next level boundary"
        );
        assert_eq!(lease.peak(), 4);
        assert_eq!(lease.width(), 4);
        drop(lease);
        assert_eq!(b.lease(100).n, 4, "drop returns the grown width");
    }

    /// A wide job must yield at a level boundary while another job is
    /// blocked on the budget — the anti-starvation half of the elastic
    /// contract (growth-only re-leasing would serialize the batch
    /// behind the first wide job).
    #[test]
    fn elastic_lease_yields_to_waiters_at_level_boundaries() {
        use std::sync::mpsc;
        use std::time::{Duration, Instant};
        let b = Arc::new(ThreadBudget::new(4));
        let big = ElasticLease::acquire(b.clone(), 4);
        assert_eq!(big.width(), 4, "a lone job grabs the whole budget");
        let (tx, rx) = mpsc::channel();
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || {
            let lease = ElasticLease::acquire(b2, 4); // blocks: budget empty
            tx.send(lease.width()).unwrap();
        });
        // poll the boundary re-lease until the waiter has registered;
        // once it has, the fair-share target must shrink the wide lease
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut w = big.width_for_level(1);
        while w == 4 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
            w = big.width_for_level(1);
        }
        assert_eq!(w, 2, "the boundary re-lease must split with the waiter");
        let granted = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("the shrink must wake the blocked leaser");
        assert!(
            (1..=2).contains(&granted),
            "the woken job gets the yielded share, got {granted}"
        );
        waiter.join().unwrap();
    }

    #[test]
    fn exhausted_budget_blocks_until_release() {
        use std::sync::mpsc;
        let b = Arc::new(ThreadBudget::new(1));
        let first = b.lease(1);
        let (tx, rx) = mpsc::channel();
        let b2 = b.clone();
        let waiter = std::thread::spawn(move || {
            let lease = b2.lease(1);
            tx.send(lease.n).unwrap();
            drop(lease);
        });
        // the waiter cannot proceed while the budget is held
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "lease must block while the budget is exhausted"
        );
        drop(first);
        let granted = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("release must wake the waiter");
        assert_eq!(granted, 1);
        waiter.join().unwrap();
    }

    /// Cold vs. warm `run_job`: the warm run is served from the cache
    /// and its core is bitwise identical to the recomputed one — the
    /// cache-correctness satellite at the API level.
    #[test]
    fn warm_job_is_cached_and_bitwise_identical() {
        let spec = scenario_job("a", "sparse-a01", 0.01, CorrKind::Pearson);
        let cache = Cache::new(64 << 20);
        let cold = run_job(&spec, &lone_lease(2), &cache, None).unwrap();
        assert_eq!(cold.corr_cache, CacheOutcome::Miss);
        assert_eq!(cold.result_cache, CacheOutcome::Miss);
        let warm = run_job(&spec, &lone_lease(1), &cache, None).unwrap();
        assert_eq!(warm.corr_cache, CacheOutcome::Mem);
        assert_eq!(warm.result_cache, CacheOutcome::Mem);
        assert!(warm.result_cache.is_hit());
        assert_eq!(cold.core, warm.core, "cached result must be bitwise equal");
        // an independent cold run recomputes the same bytes
        let fresh = run_job(&spec, &lone_lease(4), &Cache::new(64 << 20), None).unwrap();
        assert_eq!(cold.core, fresh.core);
    }

    /// Two alphas over one dataset share the correlation layer.
    #[test]
    fn corr_layer_is_shared_across_alphas() {
        let cache = Cache::new(64 << 20);
        let a = run_job(
            &scenario_job("a", "sparse-a01", 0.01, CorrKind::Pearson),
            &lone_lease(1),
            &cache,
            None,
        )
        .unwrap();
        let b = run_job(
            &scenario_job("b", "sparse-a01", 0.05, CorrKind::Pearson),
            &lone_lease(1),
            &cache,
            None,
        )
        .unwrap();
        assert_eq!(a.corr_cache, CacheOutcome::Miss);
        assert_eq!(
            b.corr_cache,
            CacheOutcome::Mem,
            "same data + kind must reuse the gram"
        );
        assert_eq!(
            b.result_cache,
            CacheOutcome::Miss,
            "different alpha is a different result"
        );
        // Spearman over the same data is a different correlation identity
        let c = run_job(
            &scenario_job("c", "sparse-a01", 0.01, CorrKind::Spearman),
            &lone_lease(1),
            &cache,
            None,
        )
        .unwrap();
        assert_eq!(c.corr_cache, CacheOutcome::Miss);
    }

    #[test]
    fn run_batch_is_job_thread_invariant_and_ordered() {
        let manifest = Manifest {
            jobs: vec![
                scenario_job("one", "sparse-a01", 0.01, CorrKind::Pearson),
                scenario_job("two", "sparse-a01", 0.05, CorrKind::Pearson),
                scenario_job("three", "grn-mid", 0.01, CorrKind::Pearson),
                scenario_job("four", "rank-er", 0.01, CorrKind::Spearman),
            ],
        };
        let run = |job_threads: usize| {
            let cache = Cache::new(64 << 20);
            let out = run_batch(
                &manifest,
                &BatchOptions {
                    job_threads,
                    threads: 4,
                    ..BatchOptions::default()
                },
                &cache,
            )
            .unwrap();
            assert!(out.disk.is_none(), "no --cache-dir, no disk stats");
            render_results(&manifest.jobs, &out.reports)
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(4));
        assert_eq!(serial.lines().count(), 4);
    }

    /// A failure must stop the queue: later jobs are skipped, not run.
    #[test]
    fn a_failing_job_stops_the_queue() {
        let manifest = Manifest {
            jobs: vec![
                JobSpec {
                    name: "bad".into(),
                    source: DataSource::Csv("no/such/file.csv".into()),
                    family: FamilyId::Pc(Variant::CupcS),
                    alpha: 0.01,
                    max_level: None,
                    corr: CorrKind::Pearson,
                    orient: OrientRule::Standard,
                },
                scenario_job("later", "sparse-a01", 0.01, CorrKind::Pearson),
            ],
        };
        let cache = Cache::new(1 << 20);
        let err = run_batch(&manifest, &BatchOptions::default(), &cache)
            .expect_err("the bad job must fail the batch");
        assert!(format!("{err:#}").contains("job #0"), "{err:#}");
        // the bad job dies before touching the cache, so any cache
        // traffic would mean the second job ran after the failure
        let st = cache.stats();
        assert_eq!(
            st.hits + st.misses,
            0,
            "the queue must stop before the next job starts: {st:?}"
        );
    }

    #[test]
    fn batch_errors_name_the_failing_job() {
        let manifest = Manifest {
            jobs: vec![JobSpec {
                name: "missing".into(),
                source: DataSource::Csv("definitely/not/here.csv".into()),
                family: FamilyId::Pc(Variant::CupcS),
                alpha: 0.01,
                max_level: None,
                corr: CorrKind::Pearson,
                orient: OrientRule::Standard,
            }],
        };
        let err = run_batch(
            &manifest,
            &BatchOptions::default(),
            &Cache::new(1 << 20),
        )
        .expect_err("missing CSV must fail the batch");
        let msg = format!("{err:#}");
        assert!(msg.contains("missing"), "{msg}");
        assert!(msg.contains("not/here.csv"), "{msg}");
    }

    /// Disk tier through `run_job`: a fresh in-process cache with a warm
    /// store serves both layers from disk, bitwise identical.
    #[test]
    fn disk_tier_serves_a_fresh_process_bitwise() {
        let dir = std::env::temp_dir().join(format!(
            "cupc_sched_disk_{}_fresh",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = DiskStore::open(&dir, 64 << 20).unwrap();
        let spec = scenario_job("a", "sparse-a01", 0.01, CorrKind::Pearson);

        let cold = run_job(&spec, &lone_lease(2), &Cache::new(64 << 20), Some(&store)).unwrap();
        assert_eq!(cold.corr_cache, CacheOutcome::Miss);
        assert_eq!(cold.result_cache, CacheOutcome::Miss);

        // "new process": fresh memory cache, same store
        let warm = run_job(&spec, &lone_lease(1), &Cache::new(64 << 20), Some(&store)).unwrap();
        assert_eq!(warm.corr_cache, CacheOutcome::Disk);
        assert_eq!(warm.result_cache, CacheOutcome::Disk);
        assert_eq!(cold.core, warm.core, "disk round-trip must be bitwise");
        let st = store.stats();
        assert!(st.hits >= 2, "{st:?}");
        assert_eq!(st.dropped, 0, "{st:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
