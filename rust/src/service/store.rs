//! Persistent on-disk tier of the content-addressed cache
//! (`cupc batch --cache-dir`).
//!
//! cuPC's amortization story does not stop at process exit: practitioners
//! re-run PC over the same data with different alphas and variants
//! (ParallelPC, Le et al. 2015), so the expensive layers — the
//! correlation gram and whole job results — are spilled here and shared
//! by every later `cupc batch` invocation, including concurrent ones.
//!
//! Design:
//!
//! * **one file per entry**, named by the 128-bit content key, holding a
//!   fixed header (magic, [`SCHEMA_VERSION`], entry kind, the key bytes,
//!   payload length, payload checksum) followed by the raw payload;
//! * **atomic, durable writes** — payloads land in a temp file that is
//!   fsync'd and then renamed into place (plus a best-effort directory
//!   fsync), so a reader can never observe a half-written entry under
//!   its final name;
//! * **corruption is a miss, never an error** — truncation, a magic or
//!   version mismatch, a foreign key, a bad checksum, or an undecodable
//!   payload all delete the entry and fall through to recompute; results
//!   stay bit-identical because the store only ever returns
//!   checksum-validated bytes that a cold computation produced;
//! * **byte-budgeted LRU** — every read hit bumps the entry's access
//!   stamp (mtime); when the directory outgrows the budget, puts evict
//!   stalest-first, never the entry just written. An entry larger than
//!   the whole budget is not stored at all. The eviction scan is gated
//!   on a per-store byte estimate (seeded at open, snapped to ground
//!   truth by every scan), so the common put is one write + one rename;
//!   temp files orphaned by crashed writers are reaped at open;
//! * **multi-process safe** — writers in other processes use the same
//!   temp + rename protocol, and readers revalidate every byte, so a
//!   shared `--cache-dir` needs no locking beyond the filesystem's
//!   rename atomicity (gated by
//!   `tests/batch_runner.rs::concurrent_batches_share_one_cache_dir`).
//!   One benign race remains: a reader that found an entry corrupt
//!   deletes it by path, and a concurrent writer may have renamed a
//!   fresh valid entry into that path in between — costing that entry
//!   (a future recompute) and a spurious `dropped` count, never a wrong
//!   result.

use super::cache::{ContentHasher, Key};
use super::report::JobResultCore;
use anyhow::{Context, Result};
use std::fs::{self, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime};

/// Bump on ANY layout change — header or payload encodings. Old entries
/// then degrade to misses (delete + recompute) instead of misparsing.
/// (v2: `JobResultCore` gained the orientation counters. v3:
/// `JobResultCore` gained the causal-order section for the lingam
/// engine family.)
pub const SCHEMA_VERSION: u32 = 3;

const MAGIC: [u8; 4] = *b"CUPC";
/// magic 4 + version 4 + kind 1 + key 16 + payload_len 8 + checksum 16
const HEADER_LEN: usize = 49;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Kind {
    Corr,
    Result,
    /// `cupc shard` plan descriptor (schema-versioned payload — see
    /// `oocore::shard`). Additive in schema v2: older binaries treat
    /// these files as foreign and never misparse them.
    Plan,
    /// one rank's per-round exchange blob (`oocore::exchange`)
    Shard,
}

impl Kind {
    fn tag(self) -> u8 {
        match self {
            Kind::Corr => 0,
            Kind::Result => 1,
            Kind::Plan => 2,
            Kind::Shard => 3,
        }
    }

    fn prefix(self) -> &'static str {
        match self {
            Kind::Corr => "corr",
            Kind::Result => "res",
            Kind::Plan => "plan",
            Kind::Shard => "shd",
        }
    }
}

/// Aggregate counters plus a directory census (the stats stream's
/// trailing `disk` record).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DiskStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// entries deleted as truncated / version-mismatched / corrupt
    pub dropped: u64,
    pub entries: usize,
    pub bytes: u64,
    pub budget: u64,
}

#[derive(Default)]
struct Counters {
    hits: u64,
    misses: u64,
    evictions: u64,
    dropped: u64,
}

/// Handle on one persistent cache directory. Cheap to share by
/// reference across job workers; all methods take `&self`.
pub struct DiskStore {
    dir: PathBuf,
    budget: u64,
    counters: Mutex<Counters>,
    /// serializes rename + evict so one process doesn't race its own
    /// scans (the expensive tmp-file write + fsync happens outside it)
    put_lock: Mutex<()>,
    /// This store's estimate of the directory's entry bytes — seeded by
    /// a scan at open, bumped per put, snapped back to ground truth by
    /// every eviction scan. The O(entries) directory sweep only runs
    /// when this estimate exceeds the budget, so a put is normally one
    /// write + one rename. The estimate can lag writers in *other*
    /// processes, which only delays eviction — each writer re-checks
    /// exactly (from `read_dir`) whenever its own estimate trips.
    approx_bytes: AtomicU64,
}

/// Temp files are invisible to lookups and eviction; a crashed writer
/// can orphan one, so anything this stale is reaped at the next open.
/// (Live tmp files exist for milliseconds — hours of margin.)
const TMP_PREFIX: &str = ".tmp-";
const TMP_REAP_AGE: Duration = Duration::from_secs(3600);

/// Process-global temp-name counter: two `DiskStore` handles on one
/// directory inside one process (e.g. two concurrent `run_batch` calls)
/// must never hand out the same `.tmp-<pid>-<seq>` name — a per-store
/// counter would make the second writer truncate the first's in-flight
/// file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn reap_stale_tmp(dir: &Path) {
    let rd = match fs::read_dir(dir) {
        Ok(r) => r,
        Err(_) => return,
    };
    let now = SystemTime::now();
    for e in rd.flatten() {
        let path = e.path();
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with(TMP_PREFIX))
            .unwrap_or(false);
        if !is_tmp {
            continue;
        }
        // only a *provably* old tmp is an orphan — unreadable metadata
        // or an mtime at/after our `now` snapshot means a live writer
        // may own it (another store can create one mid-scan), and
        // deleting that would tear an in-flight put
        let stale = e
            .metadata()
            .and_then(|md| md.modified())
            .ok()
            .and_then(|mtime| now.duration_since(mtime).ok())
            .map(|age| age >= TMP_REAP_AGE)
            .unwrap_or(false);
        if stale {
            let _ = fs::remove_file(&path);
        }
    }
}

fn checksum(payload: &[u8]) -> Key {
    let mut h = ContentHasher::new();
    h.write(payload);
    h.finish()
}

/// Is this file name one of ours? Matches the exact shape
/// [`DiskStore::entry_path`] writes — `<prefix>-<32 hex digits>.bin`,
/// with the prefixes derived from [`Kind::prefix`] so the writer and
/// the scanners can never disagree. Anything else (temp files, a
/// user's `res-backup.bin`) is foreign: never counted against the
/// budget, never evicted.
fn is_entry_name(name: &str) -> bool {
    let stem = match name.strip_suffix(".bin") {
        Some(s) => s,
        None => return false,
    };
    [Kind::Corr, Kind::Result, Kind::Plan, Kind::Shard].into_iter().any(|k| {
        stem.strip_prefix(k.prefix())
            .and_then(|rest| rest.strip_prefix('-'))
            .is_some_and(|key| key.len() == 32 && key.bytes().all(|b| b.is_ascii_hexdigit()))
    })
}

impl DiskStore {
    /// Open (creating if needed) a persistent store rooted at `dir` with
    /// a byte `budget` for entry payloads + headers. Reaps temp files
    /// orphaned by crashed writers and seeds the byte estimate from the
    /// directory's current contents. A zero budget is rejected loudly —
    /// it would make every put a silent no-op, the exact downgrade the
    /// writability probe below exists to prevent.
    pub fn open(dir: &Path, budget: u64) -> Result<DiskStore> {
        anyhow::ensure!(
            budget > 0,
            "disk cache budget is zero — raise --cache-disk-mb or drop --cache-dir"
        );
        fs::create_dir_all(dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        // probe writability up front: an unwritable --cache-dir must
        // fail the batch loudly here (run_batch's contract) — if it only
        // surfaced in put(), which swallows I/O errors by design, the
        // user would silently get zero persistence
        let probe = dir.join(format!("{TMP_PREFIX}probe-{}", std::process::id()));
        fs::write(&probe, b"cupc")
            .with_context(|| format!("cache dir {} is not writable", dir.display()))?;
        let _ = fs::remove_file(&probe);
        reap_stale_tmp(dir);
        let store = DiskStore {
            dir: dir.to_path_buf(),
            budget,
            counters: Mutex::new(Counters::default()),
            put_lock: Mutex::new(()),
            approx_bytes: AtomicU64::new(0),
        };
        let (_, bytes) = store.census();
        store.approx_bytes.store(bytes, Ordering::Relaxed);
        Ok(store)
    }

    fn entry_path(&self, kind: Kind, key: Key) -> PathBuf {
        self.dir
            .join(format!("{}-{:016x}{:016x}.bin", kind.prefix(), key.0, key.1))
    }

    fn count<F: FnOnce(&mut Counters)>(&self, f: F) {
        f(&mut self.counters.lock().unwrap());
    }

    /// Read + fully validate one entry. `Some(payload)` only when every
    /// header field and the checksum agree; any mismatch deletes the
    /// file and counts `dropped`. A missing file is simply `None`.
    /// Counters for hit/miss are the caller's job (a checksum-valid
    /// payload can still fail to decode).
    fn load(&self, kind: Kind, key: Key) -> Option<Vec<u8>> {
        let path = self.entry_path(kind, key);
        let mut raw = match fs::read(&path) {
            Ok(r) => r,
            Err(_) => return None,
        };
        let valid = raw.len() >= HEADER_LEN
            && raw[0..4] == MAGIC
            && u32::from_le_bytes(raw[4..8].try_into().unwrap()) == SCHEMA_VERSION
            && raw[8] == kind.tag()
            && u64::from_le_bytes(raw[9..17].try_into().unwrap()) == key.0
            && u64::from_le_bytes(raw[17..25].try_into().unwrap()) == key.1
            && u64::from_le_bytes(raw[25..33].try_into().unwrap())
                == (raw.len() - HEADER_LEN) as u64
            && {
                let want = (
                    u64::from_le_bytes(raw[33..41].try_into().unwrap()),
                    u64::from_le_bytes(raw[41..49].try_into().unwrap()),
                );
                checksum(&raw[HEADER_LEN..]) == want
            };
        if !valid {
            self.drop_entry(&path);
            return None;
        }
        Some(raw.split_off(HEADER_LEN))
    }

    fn drop_entry(&self, path: &Path) {
        let _ = fs::remove_file(path);
        self.count(|c| c.dropped += 1);
    }

    /// Bump the LRU access stamp (best-effort — a failed touch only
    /// worsens this entry's eviction odds, never correctness).
    fn touch(&self, kind: Kind, key: Key) {
        if let Ok(f) = OpenOptions::new()
            .append(true)
            .open(self.entry_path(kind, key))
        {
            let _ = f.set_modified(SystemTime::now());
        }
    }

    /// Correlation matrix for `key`, validated against the expected
    /// element count (n²). A checksum-valid entry of the wrong shape can
    /// only be a key collision — dropped like corruption.
    pub fn get_corr(&self, key: Key, expected_len: usize) -> Option<Vec<f64>> {
        let payload = self.load(Kind::Corr, key);
        let decoded = payload.and_then(|p| {
            if p.len() != expected_len.checked_mul(8)? {
                self.drop_entry(&self.entry_path(Kind::Corr, key));
                return None;
            }
            let mut v = Vec::with_capacity(expected_len);
            for chunk in p.chunks_exact(8) {
                v.push(f64::from_le_bytes(chunk.try_into().unwrap()));
            }
            Some(v)
        });
        match decoded {
            Some(v) => {
                self.touch(Kind::Corr, key);
                self.count(|c| c.hits += 1);
                Some(v)
            }
            None => {
                self.count(|c| c.misses += 1);
                None
            }
        }
    }

    /// Persist a correlation matrix (exact bit patterns — the cached
    /// and recomputed grams are bitwise interchangeable). Builds the
    /// byte payload up front, transiently doubling the gram's
    /// footprint; at this repo's workload sizes that is MB-scale. If
    /// grams ever reach GB-scale, stream the chunks instead — the
    /// checksum hasher is chunking-invariant, so no format change.
    pub fn put_corr(&self, key: Key, corr: &[f64]) {
        let mut payload = Vec::with_capacity(corr.len() * 8);
        for x in corr {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        self.put(Kind::Corr, key, &payload);
    }

    /// Job result core for `key`; an undecodable payload is dropped.
    pub fn get_result(&self, key: Key) -> Option<JobResultCore> {
        let decoded = self.load(Kind::Result, key).and_then(|p| {
            let core = JobResultCore::from_bytes(&p);
            if core.is_none() {
                self.drop_entry(&self.entry_path(Kind::Result, key));
            }
            core
        });
        match decoded {
            Some(core) => {
                self.touch(Kind::Result, key);
                self.count(|c| c.hits += 1);
                Some(core)
            }
            None => {
                self.count(|c| c.misses += 1);
                None
            }
        }
    }

    /// Persist a job result core.
    pub fn put_result(&self, key: Key, core: &JobResultCore) {
        self.put(Kind::Result, key, &core.to_bytes());
    }

    /// Persist a `cupc shard` plan descriptor (opaque schema-versioned
    /// bytes — `oocore::shard` owns the payload format).
    pub fn put_plan(&self, key: Key, payload: &[u8]) {
        self.put(Kind::Plan, key, payload);
    }

    /// Plan descriptor bytes for `key` (checksum-validated; corruption
    /// is a miss like every other kind).
    pub fn get_plan(&self, key: Key) -> Option<Vec<u8>> {
        match self.load(Kind::Plan, key) {
            Some(p) => {
                self.touch(Kind::Plan, key);
                self.count(|c| c.hits += 1);
                Some(p)
            }
            None => {
                self.count(|c| c.misses += 1);
                None
            }
        }
    }

    /// Persist one rank's per-round exchange blob. The shard protocol
    /// relies on rename-atomicity only: a blob is either absent or
    /// complete, never half-visible.
    pub fn put_shard(&self, key: Key, payload: &[u8]) {
        self.put(Kind::Shard, key, payload);
    }

    /// Exchange blob for `key`. Polled by waiting ranks, so a miss is
    /// the *common* case and is not counted against the miss stat
    /// (which reports cache effectiveness, not barrier latency).
    pub fn get_shard(&self, key: Key) -> Option<Vec<u8>> {
        self.load(Kind::Shard, key)
    }

    /// Write one entry atomically (temp + fsync + rename), then enforce
    /// the byte budget. Caching is best-effort: every I/O failure is
    /// swallowed — the worst outcome is a future recompute. The
    /// expensive part — writing and fsync'ing the temp file — happens
    /// outside `put_lock`, so concurrent workers only serialize on the
    /// rename + (budget-triggered) eviction scan.
    fn put(&self, kind: Kind, key: Key, payload: &[u8]) {
        let total = (HEADER_LEN + payload.len()) as u64;
        if total > self.budget {
            return; // would evict everything and still not fit
        }
        let final_path = self.entry_path(kind, key);
        let tmp = self.dir.join(format!(
            "{TMP_PREFIX}{}-{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let written = (|| -> std::io::Result<()> {
            let mut f = fs::File::create(&tmp)?;
            let mut header = [0u8; HEADER_LEN];
            header[0..4].copy_from_slice(&MAGIC);
            header[4..8].copy_from_slice(&SCHEMA_VERSION.to_le_bytes());
            header[8] = kind.tag();
            header[9..17].copy_from_slice(&key.0.to_le_bytes());
            header[17..25].copy_from_slice(&key.1.to_le_bytes());
            header[25..33].copy_from_slice(&(payload.len() as u64).to_le_bytes());
            let ck = checksum(payload);
            header[33..41].copy_from_slice(&ck.0.to_le_bytes());
            header[41..49].copy_from_slice(&ck.1.to_le_bytes());
            f.write_all(&header)?;
            f.write_all(payload)?;
            f.sync_all() // durable before it becomes visible
        })();
        if written.is_err() {
            let _ = fs::remove_file(&tmp);
            return;
        }
        {
            let _guard = self.put_lock.lock().unwrap();
            if fs::rename(&tmp, &final_path).is_err() {
                let _ = fs::remove_file(&tmp);
                return;
            }
            // re-putting an existing key double-counts; that only means
            // the next eviction check fires early and snaps the
            // estimate back
            let approx = self.approx_bytes.fetch_add(total, Ordering::Relaxed) + total;
            if approx > self.budget {
                self.evict_locked(&final_path);
            }
        }
        // make the rename itself durable where the platform allows —
        // pure durability, so it runs after the lock is released
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
    }

    /// One directory walk shared by eviction, the census, and the
    /// open-time seed: every entry file as (mtime, byte length, path).
    /// Keeping a single definition of "what is an entry" means stats,
    /// the byte estimate, and eviction can never disagree.
    fn scan_entries(&self) -> Vec<(SystemTime, u64, PathBuf)> {
        let mut entries = Vec::new();
        let rd = match fs::read_dir(&self.dir) {
            Ok(r) => r,
            Err(_) => return entries,
        };
        for e in rd.flatten() {
            let path = e.path();
            let is_entry = path
                .file_name()
                .and_then(|n| n.to_str())
                .map(is_entry_name)
                .unwrap_or(false);
            if !is_entry {
                continue;
            }
            let md = match e.metadata() {
                Ok(m) if m.is_file() => m,
                _ => continue,
            };
            let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            entries.push((mtime, md.len(), path));
        }
        entries
    }

    /// Enforce the byte budget: remove stalest-by-mtime entries until the
    /// directory fits, never touching `keep` (the entry just written) or
    /// non-entry files. Caller holds `put_lock`. Also snaps the byte
    /// estimate back to the scan's ground truth.
    fn evict_locked(&self, keep: &Path) {
        let mut entries = self.scan_entries();
        let mut total: u64 = entries.iter().map(|(_, len, _)| len).sum();
        if total > self.budget {
            // hysteresis: shrink to a low-water mark (7/8 of the
            // budget), not to the brim — otherwise at steady state the
            // very next put would re-trigger this whole scan
            let low_water = self.budget - self.budget / 8;
            // stalest first; path tie-break keeps same-stamp order stable
            entries.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.2.cmp(&b.2)));
            for (_, len, path) in entries {
                if total <= low_water {
                    break;
                }
                if path == *keep {
                    continue;
                }
                if fs::remove_file(&path).is_ok() {
                    total -= len;
                    self.count(|c| c.evictions += 1);
                }
            }
        }
        self.approx_bytes.store(total, Ordering::Relaxed);
    }

    /// Count of entry files and their total bytes, from the directory.
    fn census(&self) -> (usize, u64) {
        let entries = self.scan_entries();
        let bytes = entries.iter().map(|(_, len, _)| len).sum();
        (entries.len(), bytes)
    }

    /// Counters plus a live directory census.
    pub fn stats(&self) -> DiskStats {
        let (entries, bytes) = self.census();
        let c = self.counters.lock().unwrap();
        DiskStats {
            hits: c.hits,
            misses: c.misses,
            evictions: c.evictions,
            dropped: c.dropped,
            entries,
            bytes,
            budget: self.budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::report::LevelRow;
    use std::time::Duration;

    /// Fresh store under a unique temp dir (tests run concurrently).
    fn tmp_store(tag: &str, budget: u64) -> (DiskStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "cupc_store_{}_{tag}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let store = DiskStore::open(&dir, budget).unwrap();
        (store, dir)
    }

    fn toy_core() -> JobResultCore {
        JobResultCore {
            n: 4,
            m: 100,
            orient: crate::service::report::OrientRow {
                triples: 2,
                census_tests: 7,
                meek_sweeps: 1,
            },
            levels: vec![LevelRow {
                level: 0,
                tests: 6,
                removed: 2,
                edges_after: 4,
            }],
            skeleton_edges: vec![(0, 1), (1, 2)],
            directed: vec![(0, 1)],
            undirected: vec![(1, 2)],
            order: vec![],
        }
    }

    /// An unusable cache path must fail `open` loudly (the batch-level
    /// contract) rather than silently degrade every later put. A plain
    /// file in the dir's place trips `create_dir_all` on any platform
    /// and under any privilege level.
    #[test]
    fn open_fails_loudly_on_an_unusable_path() {
        let file = std::env::temp_dir().join(format!(
            "cupc_store_{}_notadir",
            std::process::id()
        ));
        fs::write(&file, b"x").unwrap();
        let err = DiskStore::open(&file, 1024).expect_err("a file is not a cache dir");
        assert!(format!("{err:#}").contains("cache dir"), "{err:#}");
        let _ = fs::remove_file(&file);
    }

    #[test]
    fn corr_roundtrip_is_bitwise() {
        let (store, dir) = tmp_store("corr_rt", 1 << 20);
        // exercise exact bit patterns incl. negative zero and subnormals
        let v = vec![1.0, -0.0, f64::MIN_POSITIVE / 2.0, -0.731, 3.5e300];
        store.put_corr((1, 2), &v);
        let got = store.get_corr((1, 2), v.len()).expect("hit");
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "disk roundtrip must preserve every bit"
        );
        let st = store.stats();
        assert_eq!((st.hits, st.misses, st.dropped), (1, 0, 0));
        assert_eq!(st.entries, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn result_roundtrip_and_missing_keys() {
        let (store, dir) = tmp_store("res_rt", 1 << 20);
        let core = toy_core();
        store.put_result((7, 7), &core);
        assert_eq!(store.get_result((7, 7)).as_ref(), Some(&core));
        assert!(store.get_result((8, 8)).is_none(), "absent key is a miss");
        // a corr lookup on a result key must miss (kinds do not alias)
        assert!(store.get_corr((7, 7), 4).is_none());
        let st = store.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 2);
        assert_eq!(st.dropped, 0, "absent ≠ corrupt");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn plan_and_shard_blobs_roundtrip_without_aliasing() {
        let (store, dir) = tmp_store("shardkinds", 1 << 20);
        store.put_plan((4, 2), b"plan-bytes");
        store.put_shard((4, 2), b"shard-bytes");
        assert_eq!(store.get_plan((4, 2)).as_deref(), Some(&b"plan-bytes"[..]));
        assert_eq!(store.get_shard((4, 2)).as_deref(), Some(&b"shard-bytes"[..]));
        // same key, four kinds: none alias
        assert!(store.get_corr((4, 2), 4).is_none());
        assert!(store.get_result((4, 2)).is_none());
        assert!(store.get_shard((9, 9)).is_none(), "absent blob is None");
        // shard polling must not inflate the miss stat
        let st = store.stats();
        assert_eq!(st.hits, 1, "plan hit only; shard reads bypass counters");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_is_dropped_and_rewritable() {
        let (store, dir) = tmp_store("trunc", 1 << 20);
        let v = vec![0.25; 16];
        store.put_corr((3, 4), &v);
        let path = store.entry_path(Kind::Corr, (3, 4));
        let full = fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 5).unwrap();
        drop(f);
        assert!(store.get_corr((3, 4), 16).is_none(), "truncation is a miss");
        assert!(!path.exists(), "the corrupt entry must be deleted");
        assert_eq!(store.stats().dropped, 1);
        // the slot is clean again: recompute-and-store works
        store.put_corr((3, 4), &v);
        assert_eq!(store.get_corr((3, 4), 16), Some(v));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_is_dropped() {
        let (store, dir) = tmp_store("vers", 1 << 20);
        store.put_corr((5, 6), &[1.0; 8]);
        let path = store.entry_path(Kind::Corr, (5, 6));
        let mut raw = fs::read(&path).unwrap();
        raw[4..8].copy_from_slice(&(SCHEMA_VERSION + 1).to_le_bytes());
        fs::write(&path, &raw).unwrap();
        assert!(
            store.get_corr((5, 6), 8).is_none(),
            "a future schema version must read as a miss, not an error"
        );
        assert!(!path.exists());
        assert_eq!(store.stats().dropped, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_mismatch_is_dropped() {
        let (store, dir) = tmp_store("cksum", 1 << 20);
        store.put_result((9, 9), &toy_core());
        let path = store.entry_path(Kind::Result, (9, 9));
        let mut raw = fs::read(&path).unwrap();
        let last = raw.len() - 1;
        raw[last] ^= 0xff; // flip payload bits; header stays intact
        fs::write(&path, &raw).unwrap();
        assert!(store.get_result((9, 9)).is_none(), "bit rot is a miss");
        assert!(!path.exists());
        assert_eq!(store.stats().dropped, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn foreign_key_and_wrong_shape_are_dropped() {
        let (store, dir) = tmp_store("foreign", 1 << 20);
        store.put_corr((1, 1), &[0.5; 9]);
        // copy the entry under a different key's name (e.g. a botched
        // manual restore): the header key check must reject it
        let src = store.entry_path(Kind::Corr, (1, 1));
        let dst = store.entry_path(Kind::Corr, (2, 2));
        fs::copy(&src, &dst).unwrap();
        assert!(store.get_corr((2, 2), 9).is_none());
        assert!(!dst.exists());
        // shape mismatch: stored n² = 9, caller expects 16
        assert!(store.get_corr((1, 1), 16).is_none());
        assert!(!src.exists(), "shape mismatch also drops the entry");
        assert_eq!(store.stats().dropped, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_entries_are_not_stored() {
        let (store, dir) = tmp_store("oversize", 128);
        store.put_corr((1, 0), &[0.0; 1000]); // ≫ 128-byte budget
        assert!(store.get_corr((1, 0), 1000).is_none());
        let st = store.stats();
        assert_eq!(st.entries, 0);
        assert_eq!(st.bytes, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_removes_stalest_entries_first() {
        // each entry: 16 f64 = 128 payload + 49 header = 177 bytes;
        // budget fits two entries but not three, with the low-water
        // mark (budget − budget/8 = 363) still above two entries (354)
        // so exactly one eviction occurs
        let (store, dir) = tmp_store("evict", 2 * 177 + 60);
        let stamp = |k: Key, secs: u64| {
            let f = OpenOptions::new()
                .append(true)
                .open(store.entry_path(Kind::Corr, k))
                .unwrap();
            f.set_modified(SystemTime::UNIX_EPOCH + Duration::from_secs(secs))
                .unwrap();
        };
        store.put_corr((1, 0), &[1.0; 16]);
        stamp((1, 0), 100);
        store.put_corr((2, 0), &[2.0; 16]);
        stamp((2, 0), 200); // (1,0) is stalest
        store.put_corr((3, 0), &[3.0; 16]); // mtime = now ≫ both
        assert!(
            store.get_corr((1, 0), 16).is_none(),
            "the stalest entry is evicted"
        );
        assert!(store.get_corr((2, 0), 16).is_some(), "fresher entry survives");
        assert!(store.get_corr((3, 0), 16).is_some(), "just-written survives");
        let st = store.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.entries, 2);
        assert!(st.bytes <= st.budget, "{} > {}", st.bytes, st.budget);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_hits_bump_the_access_stamp() {
        // budget sized as in eviction_removes_stalest_entries_first
        let (store, dir) = tmp_store("touch", 2 * 177 + 60);
        let stamp = |k: Key, secs: u64| {
            let f = OpenOptions::new()
                .append(true)
                .open(store.entry_path(Kind::Corr, k))
                .unwrap();
            f.set_modified(SystemTime::UNIX_EPOCH + Duration::from_secs(secs))
                .unwrap();
        };
        store.put_corr((1, 0), &[1.0; 16]);
        stamp((1, 0), 100);
        store.put_corr((2, 0), &[2.0; 16]);
        stamp((2, 0), 200);
        // touching (1,0) via a read makes (2,0) the eviction victim
        assert!(store.get_corr((1, 0), 16).is_some());
        store.put_corr((3, 0), &[3.0; 16]);
        assert!(store.get_corr((1, 0), 16).is_some(), "recently read survives");
        assert!(store.get_corr((2, 0), 16).is_none(), "LRU entry evicted");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_ignores_foreign_files() {
        let (store, dir) = tmp_store("foreignfile", 177 + 10);
        fs::write(dir.join("README.txt"), vec![0u8; 4096]).unwrap();
        // near-miss names: right prefix/suffix but not <32 hex>.bin —
        // a user's manual backup must never be counted or evicted
        fs::write(dir.join("res-backup.bin"), vec![0u8; 4096]).unwrap();
        fs::write(dir.join("corr-old.bin"), vec![0u8; 4096]).unwrap();
        store.put_corr((1, 0), &[1.0; 16]);
        assert!(
            store.get_corr((1, 0), 16).is_some(),
            "a user's files must not count against the budget"
        );
        assert!(dir.join("README.txt").exists(), "never delete foreign files");
        assert!(dir.join("res-backup.bin").exists(), "near-miss names are foreign");
        assert!(dir.join("corr-old.bin").exists(), "near-miss names are foreign");
        assert_eq!(store.stats().entries, 1, "census counts only entries");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_budget_is_rejected_at_open() {
        let dir = std::env::temp_dir().join(format!(
            "cupc_store_{}_zerobudget",
            std::process::id()
        ));
        let err = DiskStore::open(&dir, 0).expect_err("a zero budget can cache nothing");
        assert!(format!("{err:#}").contains("budget is zero"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }

    /// A temp file orphaned by a crashed writer is reaped at the next
    /// open once it is stale; a fresh temp (another process mid-write)
    /// is left alone. Orphans must also never count against the budget
    /// or show up in the census.
    #[test]
    fn stale_orphaned_tmp_files_are_reaped_on_open() {
        let (store, dir) = tmp_store("reap", 1 << 20);
        store.put_corr((1, 0), &[1.0; 8]);
        let orphan = dir.join(format!("{TMP_PREFIX}999-0"));
        fs::write(&orphan, vec![0u8; 256]).unwrap();
        let f = OpenOptions::new().append(true).open(&orphan).unwrap();
        f.set_modified(SystemTime::now() - TMP_REAP_AGE - Duration::from_secs(60))
            .unwrap();
        drop(f);
        let fresh = dir.join(format!("{TMP_PREFIX}999-1"));
        fs::write(&fresh, vec![0u8; 256]).unwrap(); // mtime = now
        assert_eq!(store.stats().entries, 1, "tmp files are not entries");
        drop(store);
        let store = DiskStore::open(&dir, 1 << 20).unwrap();
        assert!(!orphan.exists(), "the stale orphan must be reaped");
        assert!(fresh.exists(), "an in-flight tmp must be left alone");
        assert_eq!(
            store.get_corr((1, 0), 8),
            Some(vec![1.0; 8]),
            "entries survive a reopen"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    /// Many threads hammering one store (distinct and shared keys) must
    /// never panic, and every read must return either a miss or exactly
    /// the stored bytes.
    #[test]
    fn concurrent_access_is_safe() {
        let (store, dir) = tmp_store("concurrent", 1 << 20);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let store = &store;
                scope.spawn(move || {
                    for i in 0..20u64 {
                        let key = (i % 5, 0);
                        let fill = (i % 5) as f64;
                        store.put_corr(key, &[fill; 8]);
                        if let Some(v) = store.get_corr(key, 8) {
                            assert_eq!(v, vec![fill; 8], "thread {t}");
                        }
                    }
                });
            }
        });
        let _ = fs::remove_dir_all(&dir);
    }
}
