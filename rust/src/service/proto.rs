//! The `cupc serve` wire protocol: length-prefixed JSON frames over a
//! loopback TCP stream.
//!
//! Framing: every message — request or response — is a 4-byte
//! little-endian `u32` payload length followed by exactly that many
//! bytes of UTF-8 JSON. Requests are capped at [`MAX_REQUEST_BYTES`];
//! anything larger (including the "length" read out of non-protocol
//! garbage like an HTTP request line) is a `bad-frame` error.
//!
//! Requests (client → server):
//!
//! ```json
//! {"op": "ping"}
//! {"op": "stats"}
//! {"op": "submit", "priority": "normal", "manifest": {"jobs": [...]}}
//! ```
//!
//! The `manifest` value is the same document `cupc batch --manifest`
//! reads from disk, embedded verbatim; `priority` is optional
//! (`low` | `normal` | `high`, default `normal`) and shapes only the
//! *initial* worker ask — never the result bytes.
//!
//! Responses (server → client):
//!
//! ```json
//! {"pong": true}
//! {"stats": {...}}
//! {"result": <record>}      // one per job, manifest order
//! {"done": {"jobs": N}}     // terminates a submit's stream
//! {"error": {"code": "...", "message": "..."}}
//! ```
//!
//! Each `result` frame embeds one deterministic results-stream record
//! (`service::report::result_line`) **verbatim** — the client
//! reassembles them by textual extraction ([`record_from_result_frame`])
//! so a served stream is byte-identical to the `cupc batch` results
//! file, with no JSON re-rendering in the path to prove anything about.
//!
//! Error codes: `bad-frame` (framing lost — the connection closes),
//! `bad-request` (malformed payload — the connection survives),
//! `overloaded` (admission control rejected the submit), `busy`
//! (connection cap reached), `job-failed` (a job errored — the
//! request's remaining jobs are skipped, the connection survives).

use super::job::Manifest;
use crate::util::json::{escape, Json};
use anyhow::{bail, Context, Result};

/// Request frames larger than this are rejected (`bad-frame`). Requests
/// are manifests plus small envelopes, so 8 MiB is orders of magnitude
/// beyond any real job list while bounding what one connection can make
/// the daemon buffer.
pub const MAX_REQUEST_BYTES: usize = 8 << 20;

/// Sanity cap a client applies to response frames. Responses carry
/// whole result records (edge lists included), so the cap is much
/// larger than the request cap — it exists to catch stream
/// desynchronization, not to bound honest payloads.
pub const MAX_RESPONSE_BYTES: usize = 256 << 20;

/// Prefix `payload` with its 4-byte little-endian length.
pub fn encode_frame(payload: &str) -> Vec<u8> {
    assert!(payload.len() <= u32::MAX as usize, "frame too large");
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload.as_bytes());
    out
}

/// Decode a frame header.
pub fn frame_len(header: [u8; 4]) -> usize {
    u32::from_le_bytes(header) as usize
}

/// Fair-share priority of a submit request. Shapes the *initial* lease
/// ask for each of the request's jobs against the shared
/// [`super::scheduler::ThreadBudget`]; between skeleton levels every job
/// drifts toward its fair share regardless ([`super::scheduler::ElasticLease`]),
/// and results are width-invariant by the pipeline contract — so
/// priority can only move wall-clock time, never bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    Low,
    Normal,
    High,
}

impl Priority {
    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            other => bail!("unknown priority {other:?} (low|normal|high)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Workers each of the request's jobs initially asks the shared
    /// budget for. The grant is still capped at the fair share of idle
    /// workers among concurrent leasers, so `High` expresses appetite,
    /// not preemption.
    pub fn initial_want(self, total: usize) -> usize {
        match self {
            Priority::Low => 1,
            Priority::Normal => (total / 2).max(1),
            Priority::High => total.max(1),
        }
    }
}

/// A parsed client request.
pub enum Request {
    /// run a manifest; results stream back in manifest order
    Submit {
        manifest: Manifest,
        priority: Priority,
    },
    /// daemon counters (budget, cache, disk, admission)
    Stats,
    /// liveness probe
    Ping,
}

/// Parse one request payload. Every validation failure is an error the
/// server wraps in a `bad-request` frame — the manifest rules are
/// exactly `cupc batch`'s ([`Manifest::from_jobs_json`]), so a manifest
/// rejected at the CLI is rejected identically over the wire.
pub fn parse_request(payload: &str) -> Result<Request> {
    let root = Json::parse(payload).context("request is not valid JSON")?;
    let op = root
        .get("op")
        .and_then(Json::as_str)
        .context("request must be an object with an \"op\" string")?;
    match op {
        "ping" => Ok(Request::Ping),
        "stats" => Ok(Request::Stats),
        "submit" => {
            let priority = match root.get("priority") {
                Some(v) => Priority::parse(v.as_str().context("\"priority\" must be a string")?)?,
                None => Priority::Normal,
            };
            let m = root
                .get("manifest")
                .context("submit requires a \"manifest\" object")?;
            let jobs = m
                .get("jobs")
                .and_then(Json::as_array)
                .context("manifest must be an object with a \"jobs\" array")?;
            let manifest = Manifest::from_jobs_json(jobs)?;
            Ok(Request::Submit { manifest, priority })
        }
        other => bail!("unknown op {other:?} (ping|stats|submit)"),
    }
}

/// A structured error frame.
pub fn error_frame(code: &str, message: &str) -> String {
    format!(
        "{{\"error\":{{\"code\":\"{}\",\"message\":\"{}\"}}}}",
        escape(code),
        escape(message)
    )
}

/// Wrap one deterministic result record (already valid JSON) verbatim.
pub fn result_frame(record: &str) -> String {
    format!("{{\"result\":{record}}}")
}

/// Terminate a submit's stream.
pub fn done_frame(jobs: usize) -> String {
    format!("{{\"done\":{{\"jobs\":{jobs}}}}}")
}

pub fn pong_frame() -> String {
    "{\"pong\":true}".to_string()
}

/// Extract the verbatim record from a `{"result":<record>}` frame.
/// Textual by design: the server embedded the batch layer's record
/// bytes unchanged, so textual extraction preserves bit-identity with
/// the `cupc batch` results file (a parse → re-render path would have
/// to prove float round-tripping instead). `None` for any other frame.
pub fn record_from_result_frame(payload: &str) -> Option<&str> {
    payload
        .strip_prefix("{\"result\":")
        .and_then(|rest| rest.strip_suffix('}'))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::job::DataSource;

    #[test]
    fn frames_roundtrip() {
        let f = encode_frame("abc");
        assert_eq!(f, vec![3, 0, 0, 0, b'a', b'b', b'c']);
        let header: [u8; 4] = f[..4].try_into().unwrap();
        assert_eq!(frame_len(header), 3);
        assert_eq!(frame_len([0; 4]), 0);
        // the length a server reads out of an HTTP request line is junk
        // far beyond the request cap — garbage input self-identifies
        let header: [u8; 4] = b"GET "[..4].try_into().unwrap();
        assert!(frame_len(header) > MAX_REQUEST_BYTES);
    }

    #[test]
    fn parses_ping_stats_and_submit() {
        assert!(matches!(parse_request(r#"{"op":"ping"}"#), Ok(Request::Ping)));
        assert!(matches!(
            parse_request(r#"{"op":"stats"}"#),
            Ok(Request::Stats)
        ));
        let req = parse_request(
            r#"{"op":"submit","priority":"high",
                "manifest":{"jobs":[{"name":"a","scenario":"sparse-a01"}]}}"#,
        )
        .unwrap();
        match req {
            Request::Submit { manifest, priority } => {
                assert_eq!(priority, Priority::High);
                assert_eq!(manifest.jobs.len(), 1);
                assert_eq!(manifest.jobs[0].name, "a");
                assert_eq!(
                    manifest.jobs[0].source,
                    DataSource::Scenario("sparse-a01".into())
                );
            }
            _ => panic!("expected submit"),
        }
        // priority defaults to normal
        let req =
            parse_request(r#"{"op":"submit","manifest":{"jobs":[{"scenario":"grn-mid"}]}}"#)
                .unwrap();
        assert!(matches!(
            req,
            Request::Submit {
                priority: Priority::Normal,
                ..
            }
        ));
    }

    /// Wire-side manifests go through the same validator as file-side
    /// ones — a manifest the CLI rejects is rejected identically here.
    #[test]
    fn bad_requests_are_named_errors() {
        for (payload, needle) in [
            ("[]", "\"op\" string"),
            (r#"{"op":"fly"}"#, "unknown op"),
            (r#"{"op":"submit"}"#, "\"manifest\" object"),
            (r#"{"op":"submit","manifest":7}"#, "\"jobs\" array"),
            (r#"{"op":"submit","manifest":{"jobs":[]}}"#, "no jobs"),
            (
                r#"{"op":"submit","manifest":{"jobs":[{"scenario":"nope"}]}}"#,
                "unknown scenario",
            ),
            (
                r#"{"op":"submit","priority":"asap",
                    "manifest":{"jobs":[{"scenario":"grn-mid"}]}}"#,
                "unknown priority",
            ),
        ] {
            let err = parse_request(payload).expect_err(payload);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{payload}: {msg}");
        }
    }

    #[test]
    fn priority_spellings_and_wants() {
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert_eq!(Priority::Low.initial_want(8), 1);
        assert_eq!(Priority::Normal.initial_want(8), 4);
        assert_eq!(Priority::High.initial_want(8), 8);
        // a one-worker budget still grants something to everyone
        assert_eq!(Priority::Low.initial_want(1), 1);
        assert_eq!(Priority::Normal.initial_want(1), 1);
        assert_eq!(Priority::High.initial_want(1), 1);
    }

    #[test]
    fn response_frames_are_valid_json() {
        let e = Json::parse(&error_frame("bad-request", "line1\nline\"2\"")).unwrap();
        let inner = e.get("error").unwrap();
        assert_eq!(inner.get("code").unwrap().as_str(), Some("bad-request"));
        assert_eq!(
            inner.get("message").unwrap().as_str(),
            Some("line1\nline\"2\"")
        );
        let d = Json::parse(&done_frame(7)).unwrap();
        assert_eq!(
            d.get("done").unwrap().get("jobs").unwrap().as_usize(),
            Some(7)
        );
        assert_eq!(
            Json::parse(&pong_frame()).unwrap().get("pong").unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn result_records_embed_and_extract_verbatim() {
        let record = r#"{"job":"a","levels":[{"level":0,"tests":6}]}"#;
        let frame = result_frame(record);
        assert!(Json::parse(&frame).is_ok(), "envelope must stay valid JSON");
        assert_eq!(record_from_result_frame(&frame), Some(record));
        // non-result frames extract nothing
        assert_eq!(record_from_result_frame(&done_frame(1)), None);
        assert_eq!(record_from_result_frame(&pong_frame()), None);
    }
}
