//! V-structure extraction: for every unshielded triple i — k — j (i, j
//! non-adjacent), orient i → k ← j iff k ∉ SepSet(i, j). This is the
//! only place observational data determines arrowheads directly.
//!
//! Enumeration is sharded through the skeleton's pipeline executor
//! ([`Executor::run_sharded`]): stage 1 lists one canonical window per
//! center k covering its C(deg(k), 2) neighbor pairs, stage 2 scans the
//! windows in parallel against the *frozen* CPDAG (nothing is oriented
//! until every shard returns), and stage 3 applies the collected
//! colliders in canonical (k, pair-index) order — the exact order the
//! old serial loop visited, so results are bit-identical for any thread
//! count and any shard layout.

use crate::graph::cpdag::Cpdag;
use crate::graph::sepset::SepSets;
use crate::skeleton::level0::{n_pairs, pair_at};
use crate::skeleton::pipeline::{Executor, Run};
use anyhow::Result;

/// Enumerate unshielded triples and collect colliders in canonical
/// order, sharded across the executor's workers. Returns
/// `(colliders, triples)` where `triples` counts every unshielded
/// triple scanned (collider or not — the orientation workload metric).
pub fn collect_colliders(
    exec: &mut Executor<'_>,
    g: &Cpdag,
    sepsets: &SepSets,
) -> Result<(Vec<(usize, usize, usize)>, usize)> {
    let n = g.n();
    // stage 1 (serial): one window per center, weighted by its pair count
    let mut runs: Vec<Run> = Vec::new();
    for k in 0..n {
        let deg = g.degree(k);
        let count = n_pairs(deg);
        if count > 0 {
            runs.push(Run { task: k, t0: 0, count });
        }
    }
    // stage 2 (parallel): scan pair windows against the frozen graph
    let shards = exec.run_sharded(&runs, |shard, _engine| {
        let mut colliders: Vec<(usize, usize, usize)> = Vec::new();
        let mut triples = 0usize;
        for r in shard {
            let k = r.task;
            let nbrs = g.neighbors(k);
            for t in r.t0..r.t0 + r.count {
                let (ai, bi) = pair_at(nbrs.len(), t);
                let (i, j) = (nbrs[ai], nbrs[bi]);
                if g.adjacent(i, j) {
                    continue; // shielded
                }
                triples += 1;
                // unshielded triple i - k - j: collider iff k ∉ sepset(i,j)
                if !sepsets.contains(i, j, k) {
                    colliders.push((i, k, j));
                }
            }
        }
        Ok((colliders, triples))
    })?;
    // stage 3 is the caller's: shards concatenate in canonical order
    let mut colliders = Vec::new();
    let mut triples = 0usize;
    for (c, t) in shards {
        colliders.extend(c);
        triples += t;
    }
    Ok((colliders, triples))
}

/// Apply collider orientations in the canonical order `collect_colliders`
/// produced. Conflicting colliders (a later triple wanting to re-orient
/// an existing arrowhead the other way) keep the first orientation — the
/// pcalg default behaviour, now deterministic by construction.
pub fn apply_colliders(g: &mut Cpdag, colliders: &[(usize, usize, usize)]) {
    for &(i, k, j) in colliders {
        g.orient_if_undirected(i, k);
        g.orient_if_undirected(j, k);
    }
}

/// Orient all v-structures in place (single-worker convenience entry —
/// the parallel path goes through [`collect_colliders`]). Kept for
/// direct callers and tests; bit-identical to the sharded path.
pub fn orient_v_structures(g: &mut Cpdag, sepsets: &SepSets) {
    let mut exec = Executor::pool(1);
    let (colliders, _) = collect_colliders(&mut exec, g, sepsets)
        .expect("v-structure collection is pure and cannot fail");
    apply_colliders(g, &colliders);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skel(n: usize, edges: &[(usize, usize)]) -> Cpdag {
        let mut s = vec![0u8; n * n];
        for &(a, b) in edges {
            s[a * n + b] = 1;
            s[b * n + a] = 1;
        }
        Cpdag::from_skeleton(&s, n)
    }

    #[test]
    fn collider_is_oriented() {
        // 0 - 2 - 1, 0 and 1 not adjacent, sepset(0,1) = {} (no 2)
        let mut g = skel(3, &[(0, 2), (1, 2)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_directed(0, 2));
        assert!(g.is_directed(1, 2));
    }

    #[test]
    fn mediator_stays_undirected() {
        // chain: sepset(0,1) = {2} → no collider at 2
        let mut g = skel(3, &[(0, 2), (1, 2)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[2]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_undirected(0, 2));
        assert!(g.is_undirected(1, 2));
    }

    #[test]
    fn shielded_triple_ignored() {
        // triangle: no unshielded triples at all
        let mut g = skel(3, &[(0, 1), (0, 2), (1, 2)]);
        let sep = SepSets::new();
        orient_v_structures(&mut g, &sep);
        assert_eq!(g.directed_edges().len(), 0);
    }

    #[test]
    fn missing_sepset_means_collider() {
        // pair removed at level 0 with empty sepset — k ∉ ∅ → collider.
        let mut g = skel(4, &[(0, 2), (1, 2), (2, 3)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[]);
        sep.store(0, 3, &[2]);
        sep.store(1, 3, &[2]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_directed(0, 2) && g.is_directed(1, 2));
        assert!(g.is_undirected(2, 3));
    }

    #[test]
    fn triple_count_covers_unshielded_only() {
        // star center 2 with leaves 0, 1, 3 plus a shield between 0 and
        // 1: at center 2 only the pairs (0,3) and (1,3) are unshielded
        // ((0,1) is shielded); the triples at centers 0 and 1 are
        // shielded by the edges (1,2) / (0,2), and center 3 has degree 1
        let g = skel(4, &[(0, 2), (1, 2), (3, 2), (0, 1)]);
        let sep = SepSets::new();
        let mut exec = Executor::pool(1);
        let (_, triples) = collect_colliders(&mut exec, &g, &sep).unwrap();
        assert_eq!(triples, 2);
    }

    /// The tentpole contract at module level: collider lists (contents
    /// AND order) are identical for any thread count on a graph large
    /// enough to split into real shards.
    #[test]
    fn sharded_collection_matches_single_worker_bitwise() {
        use crate::util::rng::Pcg;
        // a dense-ish random skeleton with enough pairs to exceed the
        // executor's MIN_SHARD_SLOTS at several centers
        let n = 64;
        let mut rng = Pcg::seeded(77);
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.uniform_in(0.0, 1.0) < 0.4 {
                    edges.push((i, j));
                }
            }
        }
        let g = skel(n, &edges);
        let sep = SepSets::new();
        // sprinkle some sepsets so both collider and non-collider
        // branches are exercised
        for i in 0..n {
            for j in (i + 1)..n {
                if !g.adjacent(i, j) && (i + j) % 3 == 0 {
                    sep.store(i, j, &[((i + j) % n) as u32]);
                }
            }
        }
        let mut single = Executor::pool(1);
        let (ref_colliders, ref_triples) =
            collect_colliders(&mut single, &g, &sep).unwrap();
        assert!(ref_triples > 0, "workload must contain unshielded triples");
        for threads in [2usize, 4] {
            let mut pool = Executor::pool(threads);
            let (colliders, triples) = collect_colliders(&mut pool, &g, &sep).unwrap();
            assert_eq!(colliders, ref_colliders, "threads={threads}");
            assert_eq!(triples, ref_triples, "threads={threads}");
        }
    }
}
