//! V-structure extraction: for every unshielded triple i — k — j (i, j
//! non-adjacent), orient i → k ← j iff k ∉ SepSet(i, j). This is the
//! only place observational data determines arrowheads directly.

use crate::graph::cpdag::Cpdag;
use crate::graph::sepset::SepSets;

/// Orient all v-structures in place. Conflicting colliders (a later
/// triple wanting to re-orient an existing arrowhead the other way) keep
/// the first orientation — the pcalg default behaviour.
pub fn orient_v_structures(g: &mut Cpdag, sepsets: &SepSets) {
    let n = g.n();
    // collect candidates first so iteration order can't see half-applied
    // orientations (PC-stable's order-independence at the triple level)
    let mut colliders: Vec<(usize, usize, usize)> = Vec::new();
    for k in 0..n {
        let nbrs = g.neighbors(k);
        for ai in 0..nbrs.len() {
            for bi in (ai + 1)..nbrs.len() {
                let (i, j) = (nbrs[ai], nbrs[bi]);
                if g.adjacent(i, j) {
                    continue; // shielded
                }
                // unshielded triple i - k - j: collider iff k not in sepset(i,j)
                if !sepsets.contains(i, j, k) {
                    colliders.push((i, k, j));
                }
            }
        }
    }
    for (i, k, j) in colliders {
        g.orient_if_undirected(i, k);
        g.orient_if_undirected(j, k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skel(n: usize, edges: &[(usize, usize)]) -> Cpdag {
        let mut s = vec![0u8; n * n];
        for &(a, b) in edges {
            s[a * n + b] = 1;
            s[b * n + a] = 1;
        }
        Cpdag::from_skeleton(&s, n)
    }

    #[test]
    fn collider_is_oriented() {
        // 0 - 2 - 1, 0 and 1 not adjacent, sepset(0,1) = {} (no 2)
        let mut g = skel(3, &[(0, 2), (1, 2)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_directed(0, 2));
        assert!(g.is_directed(1, 2));
    }

    #[test]
    fn mediator_stays_undirected() {
        // chain: sepset(0,1) = {2} → no collider at 2
        let mut g = skel(3, &[(0, 2), (1, 2)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[2]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_undirected(0, 2));
        assert!(g.is_undirected(1, 2));
    }

    #[test]
    fn shielded_triple_ignored() {
        // triangle: no unshielded triples at all
        let mut g = skel(3, &[(0, 1), (0, 2), (1, 2)]);
        let sep = SepSets::new();
        orient_v_structures(&mut g, &sep);
        assert_eq!(g.directed_edges().len(), 0);
    }

    #[test]
    fn missing_sepset_means_collider() {
        // pair removed at level 0 with empty sepset — k ∉ ∅ → collider.
        let mut g = skel(4, &[(0, 2), (1, 2), (2, 3)]);
        let sep = SepSets::new();
        sep.store(0, 1, &[]);
        sep.store(0, 3, &[2]);
        sep.store(1, 3, &[2]);
        orient_v_structures(&mut g, &sep);
        assert!(g.is_directed(0, 2) && g.is_directed(1, 2));
        assert!(g.is_undirected(2, 3));
    }
}
