//! Majority-rule v-structure orientation (Colombo & Maathuis 2014, the
//! "MPC" variant): decide each unshielded triple i — k — j by the
//! *fraction* of separating sets of (i, j) that contain k, instead of
//! the single first-found sepset.
//!
//! Why it exists here: the skeleton of PC-stable is schedule-invariant,
//! but the stored sepset is whichever separating set a schedule finds
//! *first* — so cuPC-E, cuPC-S, and the serial loop can legitimately
//! orient a triple differently (the paper inherits this from PC-stable
//! and does not address it). Re-testing every unshielded triple with a
//! deterministic census makes the full CPDAG schedule-invariant, which
//! the test suite asserts across all five schedules.
//!
//! ## The census as a batched CI workload
//!
//! The census is the orientation phase's CI-test hot spot — O(triples ×
//! Σ C(deg, l)) tests — so it runs through the same machinery as the
//! skeleton phase: stage 1 lists each triple's census sets as one
//! canonical window (`Run { task: triple, t0: 0, count: #sets }`),
//! stage 2 shards the windows across [`Executor`] workers that pack
//! per-level [`EBatch`]es and evaluate them on their own [`CiEngine`]
//! (the same `ci_e`/`level0` kernels, so census tests are counted and
//! benchmarked like skeleton tests), and stage 3 reduces the per-shard
//! `(with_k, independent)` tallies — addition commutes, so the census,
//! and hence the CPDAG, is bit-identical for any thread count and any
//! window split. The whole census reads a *frozen* skeleton (orientation
//! marks never change adjacency), so there is no apply-order subtlety at
//! all: colliders are applied after the full census, in canonical triple
//! order.

use crate::graph::cpdag::Cpdag;
use crate::skeleton::batch::{Corr32, EBatch};
use crate::skeleton::comb::{n_sets_row, CombRange};
use crate::skeleton::engine::{CiEngine, NATIVE_MAX_LEVEL};
use crate::skeleton::pipeline::{Executor, Run};
use crate::stats::fisher::{independent, tau};
use anyhow::Result;

/// Decision for one unshielded triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TripleKind {
    /// k in a minority of separating sets → collider i → k ← j
    Collider,
    /// k in a majority → non-collider (leave undirected)
    NonCollider,
    /// exactly 50/50 or no separating set found → ambiguous; leave
    /// undirected (conservative)
    Ambiguous,
}

/// The majority decision from a census tally — exact integer arithmetic
/// (`2·with_k` vs `total`), so no float threshold can wobble.
pub fn classify(with_k: u64, total: u64) -> TripleKind {
    if total == 0 {
        TripleKind::Ambiguous
    } else if 2 * with_k < total {
        TripleKind::Collider
    } else if 2 * with_k > total {
        TripleKind::NonCollider
    } else {
        TripleKind::Ambiguous
    }
}

/// Deterministic orientation-phase bookkeeping for the majority census.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CensusStats {
    /// unshielded triples put to the vote
    pub triples: usize,
    /// CI tests the census evaluated (every enumerated candidate set)
    pub tests: u64,
}

/// One unshielded triple i — k — j with its census window size.
struct Triple {
    i: u32,
    k: u32,
    j: u32,
    /// total candidate sets across both anchors and all levels — the
    /// window the executor shards
    sets: u64,
}

/// Candidate separating sets for one anchor of a triple: subsets of
/// adj(anchor) \ {i, j} of sizes 0..=lmax (the skeleton run's deepest
/// level, clamped to the engine ceiling).
fn anchor_neighbors(g: &Cpdag, anchor: u32, i: u32, j: u32) -> Vec<u32> {
    g.neighbors(anchor as usize)
        .into_iter()
        .map(|x| x as u32)
        .filter(|&x| x != i && x != j)
        .collect()
}

/// Census window size from the two anchors' neighbor counts. In an
/// unshielded triple the anchors are non-adjacent (and never their own
/// neighbors), so `adj(anchor) \ {i, j}` is exactly `adj(anchor)` — the
/// filtered list the worker enumerates has the anchor's full degree,
/// and stage 1 can size windows from a precomputed degree table instead
/// of re-scanning adjacency per triple. Saturating, like the worker's
/// segment walk, so the two can never disagree on a window size.
fn census_sets(len_i: usize, len_j: usize, lmax: usize) -> u64 {
    let mut total = 0u64;
    for len in [len_i, len_j] {
        for l in 0..=lmax.min(len) {
            total = total.saturating_add(n_sets_row(len, l));
        }
    }
    total
}

/// Per-shard census tally: `(with_k, independent_total)` for the
/// *contiguous* triple range this shard covers (`split_runs` hands out
/// contiguous task windows, so a range-local vector keeps shard memory
/// at O(shard triples), not O(all triples)) plus the number of CI tests
/// evaluated.
struct CensusAcc {
    /// first triple index this shard touches
    base: usize,
    counts: Vec<(u64, u64)>,
    tests: u64,
}

impl CensusAcc {
    fn flush_e(
        &mut self,
        batch: &mut EBatch,
        meta: &mut Vec<(u32, bool)>,
        engine: &mut dyn CiEngine,
        taul: f64,
    ) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let z = engine.ci_e(batch.l, batch.len(), &batch.c_ij, &batch.m1, &batch.m2)?;
        for (idx, &(t, has_k)) in meta.iter().enumerate() {
            self.tests += 1;
            if independent(z[idx] as f64, taul) {
                let c = &mut self.counts[t as usize - self.base];
                c.1 += 1;
                if has_k {
                    c.0 += 1;
                }
            }
        }
        batch.clear();
        meta.clear();
        Ok(())
    }

    fn flush_l0(
        &mut self,
        c_buf: &mut Vec<f32>,
        meta: &mut Vec<u32>,
        engine: &mut dyn CiEngine,
        tau0: f64,
    ) -> Result<()> {
        if c_buf.is_empty() {
            return Ok(());
        }
        let z = engine.level0(c_buf)?;
        for (idx, &t) in meta.iter().enumerate() {
            self.tests += 1;
            // the empty set never contains k
            if independent(z[idx] as f64, tau0) {
                self.counts[t as usize - self.base].1 += 1;
            }
        }
        c_buf.clear();
        meta.clear();
        Ok(())
    }
}

/// Run the sharded census and return `(with_k, independent)` per triple
/// plus the evaluated-test count. Pure with respect to `g`.
#[allow(clippy::too_many_arguments)]
fn run_census(
    exec: &mut Executor<'_>,
    g: &Cpdag,
    corr32: &Corr32,
    m: usize,
    alpha: f64,
    lmax: usize,
    triples: &[Triple],
    runs: &[Run],
) -> Result<(Vec<(u64, u64)>, u64)> {
    let shards = exec.run_sharded(runs, |shard, engine| {
        let cap = engine.batch_e().max(1);
        // runs carry ascending task indices and shards are contiguous
        // slices of them, so this shard's triples are one index range
        let base = shard.first().map(|r| r.task).unwrap_or(0);
        let hi = shard.last().map(|r| r.task + 1).unwrap_or(0);
        let mut acc = CensusAcc {
            base,
            counts: vec![(0, 0); hi - base],
            tests: 0,
        };
        // one lazily-built batch per level (censuses mix levels freely)
        let mut batches: Vec<Option<(EBatch, Vec<(u32, bool)>)>> =
            (0..=lmax).map(|_| None).collect();
        let mut l0_c: Vec<f32> = Vec::new();
        let mut l0_meta: Vec<u32> = Vec::new();
        let mut ids: Vec<u32> = Vec::new();
        for run in shard {
            let tr = &triples[run.task];
            let (i, j) = (tr.i as usize, tr.j as usize);
            let (win_lo, win_hi) = (run.t0, run.t0 + run.count);
            // walk the triple's census segments — (anchor, level) blocks
            // in canonical order — and evaluate the overlap with this
            // run's window; windows split anywhere, results can't move
            let mut seg_start = 0u64;
            'segs: for anchor in [tr.i, tr.j] {
                let nbrs = anchor_neighbors(g, anchor, tr.i, tr.j);
                for l in 0..=lmax.min(nbrs.len()) {
                    // saturate like census_sets so the walk and the
                    // stage-1 window sizes agree even at binom overflow
                    let seg_end = seg_start.saturating_add(n_sets_row(nbrs.len(), l));
                    let lo = win_lo.max(seg_start);
                    let hi = win_hi.min(seg_end);
                    if lo < hi {
                        if l == 0 {
                            l0_c.push(corr32.at(i, j));
                            l0_meta.push(run.task as u32);
                            if l0_c.len() >= cap {
                                acc.flush_l0(
                                    &mut l0_c,
                                    &mut l0_meta,
                                    engine,
                                    tau(m, 0, alpha),
                                )?;
                            }
                        } else {
                            let (batch, meta) = batches[l]
                                .get_or_insert_with(|| (EBatch::new(l, cap), Vec::new()));
                            let mut combs =
                                CombRange::new(nbrs.len(), l, lo - seg_start, hi - lo);
                            while let Some(pos) = combs.next_comb() {
                                ids.clear();
                                ids.extend(pos.iter().map(|&p| nbrs[p as usize]));
                                batch.push(corr32, i, j, &ids);
                                meta.push((run.task as u32, ids.contains(&tr.k)));
                                if batch.len() >= cap {
                                    acc.flush_e(batch, meta, engine, tau(m, l, alpha))?;
                                }
                            }
                        }
                    }
                    seg_start = seg_end;
                    if seg_start >= win_hi {
                        break 'segs;
                    }
                }
            }
        }
        acc.flush_l0(&mut l0_c, &mut l0_meta, engine, tau(m, 0, alpha))?;
        for (l, slot) in batches.iter_mut().enumerate().skip(1) {
            if let Some((batch, meta)) = slot.as_mut() {
                acc.flush_e(batch, meta, engine, tau(m, l, alpha))?;
            }
        }
        Ok(acc)
    })?;
    // reduce: per-triple tallies commute, so shard layout never matters;
    // each shard contributes only its own contiguous range
    let mut counts = vec![(0u64, 0u64); triples.len()];
    let mut tests = 0u64;
    for acc in shards {
        for (off, src) in acc.counts.iter().enumerate() {
            let dst = &mut counts[acc.base + off];
            dst.0 += src.0;
            dst.1 += src.1;
        }
        tests += acc.tests;
    }
    Ok((counts, tests))
}

/// Orient all v-structures by the majority rule, censusing through the
/// executor. `max_level` bounds the census conditioning-set size (use
/// the skeleton run's deepest level; clamped to the engine ceiling
/// [`NATIVE_MAX_LEVEL`]).
pub fn orient_v_structures_majority_with(
    exec: &mut Executor<'_>,
    g: &mut Cpdag,
    corr32: &Corr32,
    m: usize,
    alpha: f64,
    max_level: usize,
) -> Result<CensusStats> {
    let lmax = max_level.min(NATIVE_MAX_LEVEL);
    let n = g.n();
    // stage 1 (serial): unshielded triples in canonical (k, i, j) order,
    // each with its census window size — sized from one O(n²) degree
    // pass, not an adjacency rescan per triple
    let degs: Vec<usize> = (0..n).map(|v| g.degree(v)).collect();
    let mut triples: Vec<Triple> = Vec::new();
    for k in 0..n {
        let nbrs = g.neighbors(k);
        for ai in 0..nbrs.len() {
            for bi in (ai + 1)..nbrs.len() {
                let (i, j) = (nbrs[ai], nbrs[bi]);
                if g.adjacent(i, j) {
                    continue;
                }
                let sets = census_sets(degs[i], degs[j], lmax);
                triples.push(Triple {
                    i: i as u32,
                    k: k as u32,
                    j: j as u32,
                    sets,
                });
            }
        }
    }
    let runs: Vec<Run> = triples
        .iter()
        .enumerate()
        .map(|(idx, tr)| Run {
            task: idx,
            t0: 0,
            count: tr.sets,
        })
        .collect();
    // stage 2 (parallel): the census
    let (counts, tests) = run_census(exec, g, corr32, m, alpha, lmax, &triples, &runs)?;
    // stage 3 (serial): classify and apply in canonical triple order
    for (idx, tr) in triples.iter().enumerate() {
        let (with_k, total) = counts[idx];
        if classify(with_k, total) == TripleKind::Collider {
            g.orient_if_undirected(tr.i as usize, tr.k as usize);
            g.orient_if_undirected(tr.j as usize, tr.k as usize);
        }
    }
    Ok(CensusStats {
        triples: triples.len(),
        tests,
    })
}

/// Single-worker convenience entry (kept for direct callers and tests;
/// bit-identical to any pooled width).
pub fn orient_v_structures_majority(
    g: &mut Cpdag,
    corr: &crate::stats::pcorr::Corr,
    m: usize,
    alpha: f64,
    max_level: usize,
) {
    let corr32 = Corr32::from_f64(corr.c, corr.n);
    let mut exec = Executor::pool(1);
    orient_v_structures_majority_with(&mut exec, g, &corr32, m, alpha, max_level)
        .expect("native census evaluation cannot fail");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{dag::WeightedDag, sem};
    use crate::stats::corr::correlation_matrix;
    use crate::stats::pcorr::Corr;
    use crate::util::rng::Pcg;

    #[test]
    fn classify_is_exact_integer_majority() {
        assert_eq!(classify(0, 0), TripleKind::Ambiguous, "no separating set");
        assert_eq!(classify(0, 5), TripleKind::Collider);
        assert_eq!(classify(2, 5), TripleKind::Collider);
        assert_eq!(classify(3, 5), TripleKind::NonCollider);
        assert_eq!(classify(2, 4), TripleKind::Ambiguous, "exact 50/50");
        assert_eq!(classify(4, 4), TripleKind::NonCollider);
    }

    #[test]
    fn collider_detected_by_majority() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(0, 0.8), (1, 0.8)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(1));
        let c = correlation_matrix(&data, 1);
        let corr = Corr::new(&c, 3);
        // skeleton: 0-2, 1-2 (0,1 non-adjacent)
        let skel = vec![0, 0, 1, 0, 0, 1, 1, 1, 0];
        let mut g = Cpdag::from_skeleton(&skel, 3);
        orient_v_structures_majority(&mut g, &corr, data.m, 0.01, 2);
        assert!(g.is_directed(0, 2));
        assert!(g.is_directed(1, 2));
    }

    #[test]
    fn mediator_not_oriented_by_majority() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![(0, 0.9)], vec![(1, 0.9)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(2));
        let c = correlation_matrix(&data, 1);
        let corr = Corr::new(&c, 3);
        let skel = vec![0, 1, 0, 1, 0, 1, 0, 1, 0];
        let mut g = Cpdag::from_skeleton(&skel, 3);
        orient_v_structures_majority(&mut g, &corr, data.m, 0.01, 2);
        assert!(g.is_undirected(0, 1));
        assert!(g.is_undirected(1, 2));
    }

    /// A triple with *no* separating set in the census (every candidate
    /// set leaves the pair dependent) is ambiguous and must stay
    /// undirected — the conservative branch of the majority rule.
    #[test]
    fn ambiguous_triple_stays_undirected() {
        // equicorrelated: c01 = c02 = c12 = 0.9; rho(0,1|2) ≈ 0.47, so
        // neither ∅ nor {2} separates (0,1) at m = 1000 — census total 0
        let c = vec![1.0, 0.9, 0.9, 0.9, 1.0, 0.9, 0.9, 0.9, 1.0];
        let corr = Corr::new(&c, 3);
        // skeleton: unshielded triple 0 — 2 — 1
        let skel = vec![0, 0, 1, 0, 0, 1, 1, 1, 0];
        let mut g = Cpdag::from_skeleton(&skel, 3);
        let corr32 = Corr32::from_f64(corr.c, corr.n);
        let mut exec = Executor::pool(1);
        let stats =
            orient_v_structures_majority_with(&mut exec, &mut g, &corr32, 1000, 0.01, 2)
                .unwrap();
        assert_eq!(stats.triples, 1);
        assert!(stats.tests >= 2, "census still ran: ∅ twice plus {{2}}");
        assert!(g.is_undirected(0, 2), "ambiguous triple must stay undirected");
        assert!(g.is_undirected(1, 2));
    }

    /// Census tallies and the resulting CPDAG are identical for any
    /// thread count — the tentpole contract at module level.
    #[test]
    fn census_is_thread_count_invariant() {
        let dag = WeightedDag::random_er(30, 0.2, &mut Pcg::seeded(41));
        let data = sem::sample(&dag, 300, &mut Pcg::seeded(42));
        let c = correlation_matrix(&data, 1);
        let corr32 = Corr32::from_f64(&c, data.n);
        // run the real skeleton so the census sees a realistic graph
        let cfg = crate::skeleton::Config {
            variant: crate::skeleton::Variant::Serial,
            ..crate::skeleton::Config::default()
        };
        let skel = crate::skeleton::run(&c, data.n, data.m, &cfg).unwrap();
        let run_at = |threads: usize| {
            let mut g = Cpdag::from_skeleton(&skel.graph.snapshot(), data.n);
            let mut exec = Executor::pool(threads);
            let stats = orient_v_structures_majority_with(
                &mut exec, &mut g, &corr32, data.m, cfg.alpha, 3,
            )
            .unwrap();
            (g, stats)
        };
        let (g1, s1) = run_at(1);
        assert!(s1.tests > 0, "workload must evaluate census tests");
        for threads in [2usize, 4] {
            let (gn, sn) = run_at(threads);
            assert!(g1.same_as(&gn), "threads={threads}");
            assert_eq!(s1, sn, "threads={threads}");
        }
    }

    /// The motivating property: with the majority rule the final CPDAG
    /// is identical across all schedules (sepset contents no longer
    /// matter — only the skeleton, which is schedule-invariant).
    #[test]
    fn cpdag_schedule_invariant_under_majority() {
        use crate::skeleton::{run as run_skeleton, Config, Variant};
        let dag = WeightedDag::random_er(25, 0.15, &mut Pcg::seeded(5));
        let data = sem::sample(&dag, 400, &mut Pcg::seeded(6));
        let c = correlation_matrix(&data, 1);
        let mut cpdags = Vec::new();
        for v in [Variant::Serial, Variant::CupcE, Variant::CupcS] {
            let cfg = Config {
                variant: v,
                ..Config::default()
            };
            let res = run_skeleton(&c, data.n, data.m, &cfg).unwrap();
            let deepest = res.levels.last().map(|l| l.level).unwrap_or(0);
            let corr = Corr::new(&c, data.n);
            let mut g = Cpdag::from_skeleton(&res.graph.snapshot(), data.n);
            orient_v_structures_majority(&mut g, &corr, data.m, cfg.alpha, deepest);
            crate::orient::meek::apply_meek_rules(&mut g);
            cpdags.push((v, g));
        }
        let (v0, first) = &cpdags[0];
        for (v, g) in &cpdags[1..] {
            assert!(first.same_as(g), "{v:?} CPDAG differs from {v0:?}");
        }
    }
}
