//! Majority-rule v-structure orientation (Colombo & Maathuis 2014, the
//! "MPC" variant): decide each unshielded triple i — k — j by the
//! *fraction* of separating sets of (i, j) that contain k, instead of
//! the single first-found sepset.
//!
//! Why it exists here: the skeleton of PC-stable is schedule-invariant,
//! but the stored sepset is whichever separating set a schedule finds
//! *first* — so cuPC-E, cuPC-S, and the serial loop can legitimately
//! orient a triple differently (the paper inherits this from PC-stable
//! and does not address it). Re-testing every unshielded triple with a
//! deterministic census makes the full CPDAG schedule-invariant, which
//! the test suite asserts across all five schedules.

use crate::graph::cpdag::Cpdag;
use crate::skeleton::comb::{n_sets_row, CombRange};
use crate::stats::fisher::{independent, tau};
use crate::stats::pcorr::{ci_statistic, CiWorkspace, Corr};

/// Decision for one unshielded triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TripleKind {
    /// k in a minority of separating sets → collider i → k ← j
    Collider,
    /// k in a majority → non-collider (leave undirected)
    NonCollider,
    /// exactly 50/50 or no separating set found → ambiguous; leave
    /// undirected (conservative)
    Ambiguous,
}

/// Census over all separating sets of (i, j) drawn from adj(i) and
/// adj(j) in the *final* skeleton, sizes 0..=max_level: returns
/// (#sepsets containing k, #sepsets total).
#[allow(clippy::too_many_arguments)]
fn sepset_census(
    corr: &Corr,
    m: usize,
    alpha: f64,
    g: &Cpdag,
    i: usize,
    j: usize,
    k: usize,
    max_level: usize,
    ws: &mut CiWorkspace,
) -> (usize, usize) {
    let mut with_k = 0usize;
    let mut total = 0usize;
    let mut ids: Vec<usize> = Vec::new();
    for anchor in [i, j] {
        let nbrs: Vec<usize> = g
            .neighbors(anchor)
            .into_iter()
            .filter(|&x| x != i && x != j)
            .collect();
        for l in 0..=max_level.min(nbrs.len()) {
            let taul = tau(m, l, alpha);
            let total_sets = n_sets_row(nbrs.len(), l);
            let mut combs = CombRange::new(nbrs.len(), l, 0, total_sets);
            while let Some(pos) = combs.next_comb() {
                ids.clear();
                ids.extend(pos.iter().map(|&p| nbrs[p as usize]));
                let z = ci_statistic(corr, i, j, &ids, ws);
                if independent(z, taul) {
                    total += 1;
                    if ids.contains(&k) {
                        with_k += 1;
                    }
                }
            }
        }
    }
    (with_k, total)
}

/// Classify an unshielded triple by the majority rule.
#[allow(clippy::too_many_arguments)]
pub fn classify_triple(
    corr: &Corr,
    m: usize,
    alpha: f64,
    g: &Cpdag,
    i: usize,
    k: usize,
    j: usize,
    max_level: usize,
    ws: &mut CiWorkspace,
) -> TripleKind {
    let (with_k, total) = sepset_census(corr, m, alpha, g, i, j, k, max_level, ws);
    if total == 0 {
        return TripleKind::Ambiguous;
    }
    let frac = with_k as f64 / total as f64;
    if frac < 0.5 {
        TripleKind::Collider
    } else if frac > 0.5 {
        TripleKind::NonCollider
    } else {
        TripleKind::Ambiguous
    }
}

/// Orient all v-structures by the majority rule. `max_level` bounds the
/// census conditioning-set size (use the skeleton run's deepest level).
pub fn orient_v_structures_majority(
    g: &mut Cpdag,
    corr: &Corr,
    m: usize,
    alpha: f64,
    max_level: usize,
) {
    let n = g.n();
    let mut ws = CiWorkspace::new(crate::skeleton::engine::NATIVE_MAX_LEVEL);
    let mut colliders: Vec<(usize, usize, usize)> = Vec::new();
    for k in 0..n {
        let nbrs = g.neighbors(k);
        for ai in 0..nbrs.len() {
            for bi in (ai + 1)..nbrs.len() {
                let (i, j) = (nbrs[ai], nbrs[bi]);
                if g.adjacent(i, j) {
                    continue;
                }
                if classify_triple(corr, m, alpha, g, i, k, j, max_level, &mut ws)
                    == TripleKind::Collider
                {
                    colliders.push((i, k, j));
                }
            }
        }
    }
    for (i, k, j) in colliders {
        g.orient_if_undirected(i, k);
        g.orient_if_undirected(j, k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{dag::WeightedDag, sem};
    use crate::stats::corr::correlation_matrix;
    use crate::util::rng::Pcg;

    #[test]
    fn collider_detected_by_majority() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![], vec![(0, 0.8), (1, 0.8)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(1));
        let c = correlation_matrix(&data, 1);
        let corr = Corr::new(&c, 3);
        // skeleton: 0-2, 1-2 (0,1 non-adjacent)
        let skel = vec![0, 0, 1, 0, 0, 1, 1, 1, 0];
        let mut g = Cpdag::from_skeleton(&skel, 3);
        orient_v_structures_majority(&mut g, &corr, data.m, 0.01, 2);
        assert!(g.is_directed(0, 2));
        assert!(g.is_directed(1, 2));
    }

    #[test]
    fn mediator_not_oriented_by_majority() {
        let dag = WeightedDag {
            n: 3,
            parents: vec![vec![], vec![(0, 0.9)], vec![(1, 0.9)]],
        };
        let data = sem::sample(&dag, 5000, &mut Pcg::seeded(2));
        let c = correlation_matrix(&data, 1);
        let corr = Corr::new(&c, 3);
        let skel = vec![0, 1, 0, 1, 0, 1, 0, 1, 0];
        let mut g = Cpdag::from_skeleton(&skel, 3);
        orient_v_structures_majority(&mut g, &corr, data.m, 0.01, 2);
        assert!(g.is_undirected(0, 1));
        assert!(g.is_undirected(1, 2));
    }

    /// The motivating property: with the majority rule the final CPDAG
    /// is identical across all schedules (sepset contents no longer
    /// matter — only the skeleton, which is schedule-invariant).
    #[test]
    fn cpdag_schedule_invariant_under_majority() {
        use crate::skeleton::{run as run_skeleton, Config, Variant};
        let dag = WeightedDag::random_er(25, 0.15, &mut Pcg::seeded(5));
        let data = sem::sample(&dag, 400, &mut Pcg::seeded(6));
        let c = correlation_matrix(&data, 1);
        let mut cpdags = Vec::new();
        for v in [Variant::Serial, Variant::CupcE, Variant::CupcS] {
            let cfg = Config {
                variant: v,
                ..Config::default()
            };
            let res = run_skeleton(&c, data.n, data.m, &cfg).unwrap();
            let deepest = res.levels.len().saturating_sub(1);
            let corr = Corr::new(&c, data.n);
            let mut g = Cpdag::from_skeleton(&res.graph.snapshot(), data.n);
            orient_v_structures_majority(&mut g, &corr, data.m, cfg.alpha, deepest);
            crate::orient::meek::apply_meek_rules(&mut g);
            cpdags.push((v, g));
        }
        let (v0, first) = &cpdags[0];
        for (v, g) in &cpdags[1..] {
            assert!(first.same_as(g), "{v:?} CPDAG differs from {v0:?}");
        }
    }
}
