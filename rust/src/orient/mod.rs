//! Edge orientation — the second step of PC-stable: extract v-structures
//! from the sepsets (or a majority census), then apply Meek's rules to
//! orient as many remaining edges as possible.
//!
//! The paper leaves orientation on the CPU because skeleton discovery
//! dominates a single run; at service scale — many jobs over cached
//! skeletons — the serial O(n³)–O(n⁴) triple/census/Meek loops become
//! the long pole, so orientation now runs through the same
//! [`Executor`] pipeline as the skeleton phase:
//!
//! * [`vstruct`] shards unshielded-triple enumeration in canonical
//!   windows and applies colliders in canonical order;
//! * [`majority`] shards its census and routes every census CI test
//!   through the [`CiEngine`](crate::skeleton::engine::CiEngine) batch
//!   path, so orientation tests are counted (see [`OrientStats`]) and
//!   benchmarked exactly like skeleton tests;
//! * [`meek`] collects each sweep's rule firings against a *frozen*
//!   CPDAG and applies them in canonical `(rule, i, j)` order — the
//!   fixpoint is provably scan-order- and thread-count-independent.
//!
//! Determinism contract: CPDAGs (both first-sepset and majority
//! variants) are bit-identical for any thread count, any shard layout,
//! and any Meek scan order (gated by
//! `tests/conformance_engines.rs::orientation_is_thread_count_invariant`).
//! Orientation always evaluates on the native engine mirror — the
//! executor's pool workers — regardless of the skeleton engine; CI
//! semantics are identical across engines, so this is a placement
//! choice, not a numerical one.

pub mod majority;
pub mod meek;
pub mod vstruct;

use crate::graph::adj::AdjMatrix;
use crate::graph::cpdag::Cpdag;
use crate::graph::sepset::SepSets;
use crate::skeleton::batch::Corr32;
use crate::skeleton::pipeline::Executor;
use anyhow::Result;

/// Deterministic bookkeeping of one orientation run — the orientation
/// analogue of the skeleton's per-level stats. Everything here is
/// bit-identical for any thread count, so it may appear in the batch
/// service's deterministic results stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OrientStats {
    /// unshielded triples examined (v-structure or census candidates)
    pub triples: usize,
    /// CI tests evaluated by the majority census (0 under the
    /// first-sepset rule)
    pub census_tests: u64,
    /// Meek sweeps that oriented at least one edge
    pub meek_sweeps: usize,
}

/// Full orientation through an executor: skeleton + sepsets → CPDAG
/// (standard PC-stable: v-structures from the first-found sepsets, then
/// Meek rules). Bit-identical for any executor width.
pub fn orient_with(
    exec: &mut Executor<'_>,
    graph: &AdjMatrix,
    sepsets: &SepSets,
) -> Result<(Cpdag, OrientStats)> {
    let mut g = Cpdag::from_skeleton(&graph.snapshot(), graph.n());
    let (colliders, triples) = vstruct::collect_colliders(exec, &g, sepsets)?;
    vstruct::apply_colliders(&mut g, &colliders);
    let (_, meek_sweeps) = meek::apply_meek_rules_with(exec, &mut g)?;
    Ok((
        g,
        OrientStats {
            triples,
            census_tests: 0,
            meek_sweeps,
        },
    ))
}

/// Majority-rule orientation (Colombo–Maathuis MPC) through an
/// executor: re-tests every unshielded triple against a census of
/// separating sets, making the CPDAG independent of which schedule
/// found which sepset first. Needs the correlation matrix and the
/// deepest level the skeleton reached.
pub fn orient_majority_with(
    exec: &mut Executor<'_>,
    graph: &AdjMatrix,
    corr: &[f64],
    m: usize,
    alpha: f64,
    max_level: usize,
) -> Result<(Cpdag, OrientStats)> {
    let n = graph.n();
    let mut g = Cpdag::from_skeleton(&graph.snapshot(), n);
    let corr32 = Corr32::from_f64(corr, n);
    let census =
        majority::orient_v_structures_majority_with(exec, &mut g, &corr32, m, alpha, max_level)?;
    let (_, meek_sweeps) = meek::apply_meek_rules_with(exec, &mut g)?;
    Ok((
        g,
        OrientStats {
            triples: census.triples,
            census_tests: census.tests,
            meek_sweeps,
        },
    ))
}

/// Full orientation, single-worker convenience entry (kept for direct
/// callers; bit-identical to any pooled width).
pub fn orient(graph: &AdjMatrix, sepsets: &SepSets) -> Cpdag {
    let mut exec = Executor::pool(1);
    orient_with(&mut exec, graph, sepsets)
        .expect("orientation on the native engine cannot fail")
        .0
}

/// Majority-rule orientation, single-worker convenience entry.
pub fn orient_majority(
    graph: &AdjMatrix,
    corr: &[f64],
    m: usize,
    alpha: f64,
    max_level: usize,
) -> Cpdag {
    let mut exec = Executor::pool(1);
    orient_majority_with(&mut exec, graph, corr, m, alpha, max_level)
        .expect("orientation on the native engine cannot fail")
        .0
}
