//! Edge orientation — the second step of PC-stable: extract v-structures
//! from the sepsets, then apply Meek's rules to orient as many remaining
//! edges as possible. Fast relative to skeleton discovery (the paper
//! leaves it on the CPU; so do we).

pub mod majority;
pub mod meek;
pub mod vstruct;

use crate::graph::adj::AdjMatrix;
use crate::graph::cpdag::Cpdag;
use crate::graph::sepset::SepSets;

/// Full orientation: skeleton + sepsets → CPDAG (standard PC-stable:
/// v-structures from the first-found sepsets, then Meek rules).
pub fn orient(graph: &AdjMatrix, sepsets: &SepSets) -> Cpdag {
    let mut g = Cpdag::from_skeleton(&graph.snapshot(), graph.n());
    vstruct::orient_v_structures(&mut g, sepsets);
    meek::apply_meek_rules(&mut g);
    g
}

/// Majority-rule orientation (Colombo–Maathuis MPC): re-tests every
/// unshielded triple against a census of separating sets, making the
/// CPDAG independent of which schedule found which sepset first. Needs
/// the correlation matrix and the deepest level the skeleton reached.
pub fn orient_majority(
    graph: &AdjMatrix,
    corr: &[f64],
    m: usize,
    alpha: f64,
    max_level: usize,
) -> Cpdag {
    let n = graph.n();
    let mut g = Cpdag::from_skeleton(&graph.snapshot(), n);
    let view = crate::stats::pcorr::Corr::new(corr, n);
    majority::orient_v_structures_majority(&mut g, &view, m, alpha, max_level);
    meek::apply_meek_rules(&mut g);
    g
}
