//! Meek's orientation rules (Meek 1995), applied to a fixpoint:
//!
//! R1: i → k and k — j with i, j non-adjacent        ⇒ k → j
//! R2: i → k → j and i — j                           ⇒ i → j
//! R3: i — k, i — j1 → k, i — j2 → k, j1 ≁ j2        ⇒ i → k
//! R4: i — k, i — j, j → l → k with i adj l, j ≁ k   ⇒ i → k
//!
//! We implement R1–R3 plus the standard R4 (needed only with background
//! knowledge, but included for completeness as pcalg does).
//!
//! ## Snapshot-per-sweep semantics (the determinism fix)
//!
//! The rules are evaluated in *sweeps*: every sweep collects the full
//! set of firings against the **frozen** CPDAG (no edge is oriented
//! while rules are still being checked), then applies them in canonical
//! `(rule, i, j)` order — a later firing whose edge an earlier one
//! already oriented is simply moot. The previous implementation oriented
//! edges mid-scan, so which of two conflicting firings won depended on
//! the loop order (and would have depended on the thread count once
//! sharded); with frozen sweeps the firing set is a pure function of the
//! current graph and the winner is the canonically smallest firing —
//! scan-order- and thread-count-independent by construction
//! (`in_place_and_frozen_sweeps_provably_diverge` pins the old bug).
//!
//! Each sweep's rule checks are sharded across the pipeline executor
//! ([`Executor::run_weighted`]): one atomic task per undirected edge,
//! weighted by `n` (the rules scan candidate third/fourth vertices).
//! Firings are sorted canonically before applying, so shard layout can
//! never matter.

use crate::graph::cpdag::Cpdag;
use crate::skeleton::pipeline::Executor;
use anyhow::Result;

/// One rule firing: orient `i → j` because rule `rule` matched against
/// the frozen sweep snapshot. Ordering is the canonical apply order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Firing {
    pub rule: u8,
    pub i: u32,
    pub j: u32,
}

/// Collect every rule firing for the undirected edge (a, b) against the
/// frozen graph, both directions. Pure — the sweep applies nothing until
/// all edges are checked.
fn edge_firings(g: &Cpdag, a: usize, b: usize, out: &mut Vec<Firing>) {
    let n = g.n();
    for (x, y) in [(a, b), (b, a)] {
        let f = |rule: u8| Firing {
            rule,
            i: x as u32,
            j: y as u32,
        };
        // R1: w → x — y with w ≁ y  ⇒  x → y  (w = y is impossible:
        // x — y is undirected, so no arrow y → x exists)
        if (0..n).any(|w| g.is_directed(w, x) && !g.adjacent(w, y)) {
            out.push(f(1));
        }
        // R2: x → w → y with x — y  ⇒  x → y
        if (0..n).any(|w| g.is_directed(x, w) && g.is_directed(w, y)) {
            out.push(f(2));
        }
        // R3: x — w1 → y, x — w2 → y, w1 ≁ w2  ⇒  x → y
        let ws: Vec<usize> = (0..n)
            .filter(|&w| g.is_undirected(x, w) && g.is_directed(w, y))
            .collect();
        'r3: for ai in 0..ws.len() {
            for bi in (ai + 1)..ws.len() {
                if !g.adjacent(ws[ai], ws[bi]) {
                    out.push(f(3));
                    break 'r3;
                }
            }
        }
        // R4: x — y, x adj w, w → y, v → w, x — v, v ≁ y  ⇒  x → y
        'r4: for w in 0..n {
            if !g.is_directed(w, y) || !g.adjacent(x, w) {
                continue;
            }
            for v in 0..n {
                if g.is_directed(v, w) && g.is_undirected(x, v) && !g.adjacent(v, y) {
                    out.push(f(4));
                    break 'r4;
                }
            }
        }
    }
}

/// One frozen sweep: collect all firings, sharded across the executor,
/// and sort them into canonical `(rule, i, j)` apply order. Each edge
/// task runs exactly once ([`Executor::run_weighted`]'s contract) and
/// an edge scan pushes at most one firing per (rule, direction), so the
/// list is duplicate-free by construction.
fn sweep_firings(exec: &mut Executor<'_>, g: &Cpdag) -> Result<Vec<Firing>> {
    let edges = g.undirected_edges();
    if edges.is_empty() {
        return Ok(Vec::new());
    }
    // each edge's rule checks scan O(n) candidate vertices — weight by n
    let weights = vec![g.n().max(1) as u64; edges.len()];
    let shards = exec.run_weighted(&weights, |ids, _engine| {
        let mut fs: Vec<Firing> = Vec::new();
        for &e in ids {
            let (a, b) = edges[e];
            edge_firings(g, a, b, &mut fs);
        }
        Ok(fs)
    })?;
    let mut firings: Vec<Firing> = shards.into_iter().flatten().collect();
    firings.sort_unstable();
    Ok(firings)
}

/// Apply Meek rules to a fixpoint through the executor. Returns
/// `(edges_oriented, sweeps)` where `sweeps` counts the sweeps that
/// oriented at least one edge (the final empty sweep is not counted).
pub fn apply_meek_rules_with(exec: &mut Executor<'_>, g: &mut Cpdag) -> Result<(usize, usize)> {
    let mut oriented = 0usize;
    let mut sweeps = 0usize;
    loop {
        let firings = sweep_firings(exec, g)?;
        let mut applied = 0usize;
        for fd in &firings {
            if g.orient_if_undirected(fd.i as usize, fd.j as usize) {
                applied += 1;
            }
        }
        if applied == 0 {
            // a non-empty firing set always applies its canonically first
            // firing (its edge was undirected in the very snapshot that
            // produced it), so this is the genuine fixpoint
            return Ok((oriented, sweeps));
        }
        oriented += applied;
        sweeps += 1;
    }
}

/// Apply Meek rules until no rule fires (single-worker convenience
/// entry; bit-identical to any pooled width). Returns the number of
/// edges oriented.
pub fn apply_meek_rules(g: &mut Cpdag) -> usize {
    let mut exec = Executor::pool(1);
    apply_meek_rules_with(&mut exec, g)
        .expect("meek rule evaluation is pure and cannot fail")
        .0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skel(n: usize, edges: &[(usize, usize)]) -> Cpdag {
        let mut s = vec![0u8; n * n];
        for &(a, b) in edges {
            s[a * n + b] = 1;
            s[b * n + a] = 1;
        }
        Cpdag::from_skeleton(&s, n)
    }

    #[test]
    fn r1_chains_propagate() {
        // 0 → 1 — 2, 0 ≁ 2  ⇒  1 → 2
        let mut g = skel(3, &[(0, 1), (1, 2)]);
        g.orient(0, 1);
        let o = apply_meek_rules(&mut g);
        assert!(g.is_directed(1, 2));
        assert_eq!(o, 1);
    }

    #[test]
    fn r1_shielded_does_not_fire() {
        let mut g = skel(3, &[(0, 1), (1, 2), (0, 2)]);
        g.orient(0, 1);
        apply_meek_rules(&mut g);
        // R1 blocked (0 adjacent to 2); R2 needs a 0→k→2 chain: none.
        assert!(g.is_undirected(1, 2));
        assert!(g.is_undirected(0, 2));
    }

    #[test]
    fn r2_closes_triangles() {
        // 0 → 1 → 2 with 0 — 2  ⇒  0 → 2
        let mut g = skel(3, &[(0, 1), (1, 2), (0, 2)]);
        g.orient(0, 1);
        g.orient(1, 2);
        apply_meek_rules(&mut g);
        assert!(g.is_directed(0, 2));
    }

    #[test]
    fn r3_kite() {
        // i=0 — k=3; 0 — 1 → 3; 0 — 2 → 3; 1 ≁ 2  ⇒  0 → 3
        let mut g = skel(4, &[(0, 3), (0, 1), (0, 2), (1, 3), (2, 3)]);
        g.orient(1, 3);
        g.orient(2, 3);
        apply_meek_rules(&mut g);
        assert!(g.is_directed(0, 3));
    }

    /// pcalg-style R4 oracle: i=0 — k=3, i — l=2, l → k, j=1 → l,
    /// i — j, j ≁ k  ⇒  i → k — and no other rule can claim the firing
    /// (R1/R2/R3 preconditions all fail on every undirected edge here).
    #[test]
    fn r4_fires_on_the_pcalg_configuration() {
        let mut g = skel(4, &[(0, 3), (0, 2), (2, 3), (1, 2), (0, 1)]);
        g.orient(2, 3); // l → k
        g.orient(1, 2); // j → l
        let o = apply_meek_rules(&mut g);
        assert!(g.is_directed(0, 3), "R4 must orient i → k");
        assert_eq!(o, 1, "exactly the R4 firing applies");
        // the R4 preconditions' undirected edges stay undirected
        assert!(g.is_undirected(0, 1));
        assert!(g.is_undirected(0, 2));
    }

    #[test]
    fn fixpoint_terminates_and_cascades() {
        // long chain with head orientation cascades to the tail
        let n = 6;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let mut g = skel(n, &edges);
        g.orient(0, 1);
        apply_meek_rules(&mut g);
        for i in 0..n - 1 {
            assert!(g.is_directed(i, i + 1), "edge {i}");
        }
    }

    #[test]
    fn no_rules_on_plain_undirected() {
        let mut g = skel(4, &[(0, 1), (1, 2), (2, 3)]);
        let o = apply_meek_rules(&mut g);
        assert_eq!(o, 0);
        assert_eq!(g.undirected_edges().len(), 3);
    }

    /// A faithful replica of the pre-fix in-place R1 scan: orient edges
    /// the moment the rule matches, so later checks in the same pass see
    /// half-applied orientations. Kept only to prove divergence below.
    fn in_place_r1_to_fixpoint(g: &mut Cpdag) {
        let n = g.n();
        loop {
            let mut changed = false;
            for k in 0..n {
                for j in 0..n {
                    if !g.is_undirected(k, j) {
                        continue;
                    }
                    let fire = (0..n)
                        .any(|i| g.is_directed(i, k) && !g.adjacent(i, j) && i != j);
                    if fire {
                        g.orient(k, j);
                        changed = true;
                    }
                }
            }
            if !changed {
                return;
            }
        }
    }

    /// The regression the headline bugfix exists for: on the path
    /// 0 → 1 — 2 — 3 ← 4, the frozen snapshot fires R1 twice — (1→2)
    /// and (3→2) — and canonical application orients *both* toward 2.
    /// The old in-place scan instead applied 1→2 mid-pass, which made a
    /// brand-new firing 2→3 visible *within the same pass* and let it
    /// steal the 2–3 edge before the legitimate snapshot firing 3→2 was
    /// ever checked. The two semantics provably diverge on this graph;
    /// the frozen-sweep result is the canonical one.
    #[test]
    fn in_place_and_frozen_sweeps_provably_diverge() {
        let build = || {
            let mut g = skel(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
            g.orient(0, 1); // v-structure stand-ins at both ends
            g.orient(4, 3);
            g
        };
        // old semantics: scan order decides the 2–3 edge
        let mut old = build();
        in_place_r1_to_fixpoint(&mut old);
        assert!(old.is_directed(2, 3), "in-place lets the mid-pass firing win");
        // new semantics: the frozen snapshot's own firings decide
        let mut new = build();
        apply_meek_rules(&mut new);
        assert!(new.is_directed(3, 2), "frozen sweep applies the snapshot firing");
        assert!(new.is_directed(1, 2));
        assert!(
            !old.same_as(&new),
            "the two semantics must diverge on this graph — if they stop \
             diverging, this regression test has lost its witness"
        );
    }

    /// Frozen sweeps are thread-count invariant: a CPDAG big enough to
    /// shard must orient identically at every pool width.
    #[test]
    fn sweeps_are_thread_count_invariant() {
        use crate::util::rng::Pcg;
        let n = 48;
        let mut rng = Pcg::seeded(99);
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.uniform_in(0.0, 1.0) < 0.15 {
                    edges.push((i, j));
                }
            }
        }
        let run_at = |threads: usize| {
            let mut g = skel(n, &edges);
            // seed some arrows so the rules have material to propagate
            for &(a, b) in edges.iter().step_by(5) {
                g.orient(a, b);
            }
            let mut exec = Executor::pool(threads);
            let (o, s) = apply_meek_rules_with(&mut exec, &mut g).unwrap();
            (g, o, s)
        };
        let (g1, o1, s1) = run_at(1);
        assert!(o1 > 0, "workload must actually orient edges");
        for threads in [2usize, 4] {
            let (gn, on, sn) = run_at(threads);
            assert!(g1.same_as(&gn), "threads={threads}");
            assert_eq!((o1, s1), (on, sn), "threads={threads}");
        }
    }
}
