//! Meek's orientation rules (Meek 1995), applied to a fixpoint:
//!
//! R1: i → k and k — j with i, j non-adjacent        ⇒ k → j
//! R2: i → k → j and i — j                           ⇒ i → j
//! R3: i — k, i — j1 → k, i — j2 → k, j1 ≁ j2        ⇒ i → k
//! R4: i — k, i — j, j → l → k (l ≁ ... pcalg form:
//!     i — k, i — l (or i ≁ l), i — j, j → l, l → k  ⇒ i → k
//!
//! We implement R1–R3 plus the standard R4 (needed only with background
//! knowledge, but included for completeness as pcalg does).

use crate::graph::cpdag::Cpdag;

/// Apply Meek rules until no rule fires. Returns the number of edges
/// oriented.
pub fn apply_meek_rules(g: &mut Cpdag) -> usize {
    let n = g.n();
    let mut oriented = 0usize;
    loop {
        let mut changed = false;

        // R1: unshielded i → k — j  ⇒  k → j
        for k in 0..n {
            for j in 0..n {
                if !g.is_undirected(k, j) {
                    continue;
                }
                let fire = (0..n)
                    .any(|i| g.is_directed(i, k) && !g.adjacent(i, j) && i != j);
                if fire {
                    g.orient(k, j);
                    oriented += 1;
                    changed = true;
                }
            }
        }

        // R2: i → k → j with i — j  ⇒  i → j
        for i in 0..n {
            for j in 0..n {
                if !g.is_undirected(i, j) {
                    continue;
                }
                let fire = (0..n).any(|k| g.is_directed(i, k) && g.is_directed(k, j));
                if fire {
                    g.orient(i, j);
                    oriented += 1;
                    changed = true;
                }
            }
        }

        // R3: i — k, and two non-adjacent j1, j2 with i — j1 → k, i — j2 → k ⇒ i → k
        for i in 0..n {
            for k in 0..n {
                if !g.is_undirected(i, k) {
                    continue;
                }
                let js: Vec<usize> = (0..n)
                    .filter(|&j| g.is_undirected(i, j) && g.is_directed(j, k))
                    .collect();
                let mut fire = false;
                'outer: for a in 0..js.len() {
                    for b in (a + 1)..js.len() {
                        if !g.adjacent(js[a], js[b]) {
                            fire = true;
                            break 'outer;
                        }
                    }
                }
                if fire {
                    g.orient(i, k);
                    oriented += 1;
                    changed = true;
                }
            }
        }

        // R4: i — k, i — j (or i — l), j → l, l → k, j ≁ k ⇒ i → k
        for i in 0..n {
            for k in 0..n {
                if !g.is_undirected(i, k) {
                    continue;
                }
                let mut fire = false;
                'outer4: for l in 0..n {
                    if !g.is_directed(l, k) || !g.adjacent(i, l) {
                        continue;
                    }
                    for j in 0..n {
                        if g.is_directed(j, l) && g.is_undirected(i, j) && !g.adjacent(j, k) {
                            fire = true;
                            break 'outer4;
                        }
                    }
                }
                if fire {
                    g.orient(i, k);
                    oriented += 1;
                    changed = true;
                }
            }
        }

        if !changed {
            return oriented;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skel(n: usize, edges: &[(usize, usize)]) -> Cpdag {
        let mut s = vec![0u8; n * n];
        for &(a, b) in edges {
            s[a * n + b] = 1;
            s[b * n + a] = 1;
        }
        Cpdag::from_skeleton(&s, n)
    }

    #[test]
    fn r1_chains_propagate() {
        // 0 → 1 — 2, 0 ≁ 2  ⇒  1 → 2
        let mut g = skel(3, &[(0, 1), (1, 2)]);
        g.orient(0, 1);
        let o = apply_meek_rules(&mut g);
        assert!(g.is_directed(1, 2));
        assert_eq!(o, 1);
    }

    #[test]
    fn r1_shielded_does_not_fire() {
        let mut g = skel(3, &[(0, 1), (1, 2), (0, 2)]);
        g.orient(0, 1);
        apply_meek_rules(&mut g);
        // R2 may not fire either; 1-2 stays undirected? R1 blocked
        // (0 adjacent to 2). R2 needs 0→k→2 chain: none.
        // Actually 0→1 and 0—2, 1—2: no rule orients 1—2;
        // R2: i=0, j=2: need 0→k→2 — no. So undirected remains.
        assert!(g.is_undirected(1, 2) || g.is_directed(1, 2) == false);
    }

    #[test]
    fn r2_closes_triangles() {
        // 0 → 1 → 2 with 0 — 2  ⇒  0 → 2
        let mut g = skel(3, &[(0, 1), (1, 2), (0, 2)]);
        g.orient(0, 1);
        g.orient(1, 2);
        apply_meek_rules(&mut g);
        assert!(g.is_directed(0, 2));
    }

    #[test]
    fn r3_kite() {
        // i=0 — k=3; 0 — 1 → 3; 0 — 2 → 3; 1 ≁ 2  ⇒  0 → 3
        let mut g = skel(4, &[(0, 3), (0, 1), (0, 2), (1, 3), (2, 3)]);
        g.orient(1, 3);
        g.orient(2, 3);
        apply_meek_rules(&mut g);
        assert!(g.is_directed(0, 3));
    }

    #[test]
    fn fixpoint_terminates_and_cascades() {
        // long chain with head orientation cascades to the tail
        let n = 6;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let mut g = skel(n, &edges);
        g.orient(0, 1);
        apply_meek_rules(&mut g);
        for i in 0..n - 1 {
            assert!(g.is_directed(i, i + 1), "edge {i}");
        }
    }

    #[test]
    fn no_rules_on_plain_undirected() {
        let mut g = skel(4, &[(0, 1), (1, 2), (2, 3)]);
        let o = apply_meek_rules(&mut g);
        assert_eq!(o, 0);
        assert_eq!(g.undirected_edges().len(), 3);
    }
}
