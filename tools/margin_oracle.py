#!/usr/bin/env python3
"""Margin oracle for the conformance scenario grid.

Replicates rust/src/util/rng.rs (PCG-XSH-RR 64/32 + Box-Muller),
sim/dag.rs::random_er, sim/sem.rs::sample and stats/corr.rs, then runs an
*exhaustive* PC-stable level loop (superset of every schedule's tests) and
records min |z - tau| over every evaluated CI test. If that margin is >>
1e-5 for a scenario, f32 packing cannot flip any decision, so all seven
Rust schedules must produce bit-identical skeletons there.

Kernel-delta mode (`--kernel-delta [EPS]`, see docs/NUMERICS.md): the
same sweep additionally evaluates every z statistic a second way — the
sequential-order mirror of the Rust scalar kernel (ascending-k
accumulation, ascending-c H updates) against numpy's reassociated
(pairwise-summed) matmul — and reports, per grid point, the max
|z_seq - z_reassoc| together with a verdict-equality check. The point:
today's `blocked` kernel reproduces the scalar operation order exactly
(bitwise, delta 0 by construction); a FUTURE kernel that reassociates
is verdict-safe iff its per-test z delta stays below the worst grid
margin — this mode measures a realistic reassociation delta and checks
it clears that bar (optionally against an explicit EPS bound).
"""
import math
import sys
import numpy as np

M64 = (1 << 64) - 1
PCG_MULT = 6364136223846793005
F64_MIN_POSITIVE = 2.2250738585072014e-308


class Pcg:
    def __init__(self, seed, stream):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M64
        self.spare = None
        self.next_u32()
        self.state = (self.state + seed) & M64
        self.next_u32()

    def next_u32(self):
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & M64
        xorshifted = (((old >> 18) ^ old) >> 27) & 0xFFFFFFFF
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & 0xFFFFFFFF

    def next_u64(self):
        return ((self.next_u32() << 32) | self.next_u32()) & M64

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()

    def bernoulli(self, p):
        return self.uniform() < p

    def normal(self):
        if self.spare is not None:
            s = self.spare
            self.spare = None
            return s
        while True:
            u = self.uniform()
            if u <= F64_MIN_POSITIVE:
                continue
            v = self.uniform()
            r = math.sqrt(-2.0 * math.log(u))
            ang = 2.0 * math.pi * v
            self.spare = r * math.sin(ang)
            return r * math.cos(ang)


def random_er(n, d, rng):
    parents = [[] for _ in range(n)]
    for i in range(1, n):
        for j in range(i):
            if rng.bernoulli(d):
                parents[i].append((j, rng.uniform_in(0.1, 1.0)))
    return parents


def random_grn(n, avg_parents, max_parents, rng):
    """Mirror of sim/dag.rs::random_grn, draw for draw.

    Note the Rust HashSet is only ever *iterated* after a sort, so set
    semantics (dedup) are the only thing that matters — a Python set
    matches.
    """
    parents = [[] for _ in range(n)]
    popularity = [1.0] * n
    for i in range(1, n):
        lam = min(avg_parents, float(i))
        k = 0
        acc = rng.uniform()
        p = math.exp(-lam)
        cdf = p
        while acc > cdf and k < max_parents:
            k += 1
            p *= lam / k
            cdf += p
        k = min(k, i)
        chosen = set()
        total = sum(popularity[:i])
        guard = 0
        while len(chosen) < k and guard < 50 * k + 50:
            guard += 1
            r = rng.uniform() * total
            pick = 0
            for idx in range(i):
                r -= popularity[idx]
                if r <= 0.0:
                    pick = idx
                    break
            chosen.add(pick)
        for j in sorted(chosen):
            parents[i].append((j, rng.uniform_in(0.1, 1.0)))
            popularity[j] += 1.0
    return parents


def sem_sample(parents, n, m, rng):
    x = np.zeros((m, n))
    for s in range(m):
        row = x[s]
        for i in range(n):
            v = rng.normal()
            for j, w in parents[i]:
                v += w * row[j]
            row[i] = v
    return x


def correlation(x):
    m, n = x.shape
    mean = x.mean(axis=0)
    sd = np.sqrt(((x - mean) ** 2).sum(axis=0) / m)
    inv = np.where(sd > 1e-12, 1.0 / (sd * math.sqrt(m)), 0.0)
    xs = (x - mean) * inv
    c = xs.T @ xs
    np.fill_diagonal(c, 1.0)
    return c


def spearman_correlation(x):
    """Mirror of stats/corr.rs::spearman_correlation_matrix: average
    ranks (ties averaged) per column, then the Pearson gram."""
    m, n = x.shape
    ranked = np.zeros_like(x)
    for v in range(n):
        order = sorted(range(m), key=lambda s: x[s, v])
        s = 0
        while s < m:
            e = s
            while e + 1 < m and x[order[e + 1], v] == x[order[s], v]:
                e += 1
            avg = (s + e) / 2.0 + 1.0
            for sample in order[s:e + 1]:
                ranked[sample, v] = avg
            s = e + 1
    return correlation(ranked)


def phi_inv(p):
    from statistics import NormalDist
    return NormalDist().inv_cdf(p)


def fisher_z(rho):
    r = min(max(rho, -0.9999999), 0.9999999)
    return abs(0.5 * math.log((1.0 + r) / (1.0 - r)))


def partial_corr(c, i, j, S):
    if not S:
        return c[i, j]
    m2 = c[np.ix_(S, S)]
    m1 = np.vstack([c[i, S], c[j, S]])
    m2i = np.linalg.pinv(m2, rcond=1e-10, hermitian=True)
    w = m1 @ m2i
    h = w @ m1.T
    h00 = 1.0 - h[0, 0]
    h11 = 1.0 - h[1, 1]
    h01 = c[i, j] - h[0, 1]
    return h01 / math.sqrt(max(h00 * h11, 1e-12))


def partial_corr_seq(c, i, j, S):
    """Sequential-order mirror of the Rust scalar kernel's z_from_packed
    (skeleton/engine.rs → stats/kernels/scalar.rs): ascending-k
    accumulation into acc, ascending-c updates of h00/h01/h11 — the
    exact per-lane operation order the blocked kernel also reproduces.
    Differs from partial_corr only by summation order (numpy matmul
    reassociates), so the pair measures a realistic reassociation delta.
    """
    if not S:
        return c[i, j]
    l = len(S)
    m2 = c[np.ix_(S, S)]
    m2i = np.linalg.pinv(m2, rcond=1e-10, hermitian=True)
    m1 = [[c[i, s] for s in S], [c[j, s] for s in S]]
    h00 = h01 = h11 = 0.0
    for r in range(2):
        for col in range(l):
            acc = 0.0
            for k in range(l):
                acc += m1[r][k] * m2i[k, col]
            if r == 0:
                h00 += acc * m1[0][col]
                h01 += acc * m1[1][col]
            else:
                h11 += acc * m1[1][col]
    h00 = 1.0 - h00
    h11 = 1.0 - h11
    h01 = c[i, j] - h01
    return h01 / math.sqrt(max(h00 * h11, 1e-12))


from itertools import combinations


def run_scenario(name, n, m, topology, alpha, cap, seed, corr_kind="pearson",
                 kernel_delta=False):
    if topology[0] == "er":
        parents = random_er(n, topology[1], Pcg(seed, 1))
    else:
        parents = random_grn(n, topology[1], topology[2], Pcg(seed, 1))
    x = sem_sample(parents, n, m, Pcg(seed, 2))
    c = spearman_correlation(x) if corr_kind == "spearman" else correlation(x)
    adj = np.ones((n, n), dtype=bool)
    np.fill_diagonal(adj, False)
    min_margin = float("inf")
    max_delta = 0.0
    verdict_mismatches = 0
    levels = []
    total_tests = 0
    l = 0
    while True:
        dof = m - l - 3
        tau = phi_inv(1.0 - alpha / 2.0) / math.sqrt(dof) if dof > 0 else float("inf")
        snap = adj.copy()
        to_remove = set()
        for i in range(n):
            row = [j for j in range(n) if snap[i, j]]
            if len(row) < l + 1:
                continue
            for j in row:
                pool = [k for k in row if k != j]
                for S in combinations(pool, l):
                    total_tests += 1
                    z = fisher_z(partial_corr(c, i, j, list(S)))
                    if math.isfinite(tau):
                        min_margin = min(min_margin, abs(z - tau))
                    if kernel_delta:
                        z_seq = fisher_z(partial_corr_seq(c, i, j, list(S)))
                        max_delta = max(max_delta, abs(z - z_seq))
                        if (z <= tau) != (z_seq <= tau):
                            verdict_mismatches += 1
                    if z <= tau:
                        to_remove.add((min(i, j), max(i, j)))
        for (a, b) in to_remove:
            adj[a, b] = adj[b, a] = False
        edges_after = int(adj.sum()) // 2
        levels.append((l, len(to_remove), edges_after))
        l += 1
        if cap is not None and l > cap:
            break
        if int(adj.sum(axis=1).max()) <= l:
            break
    if kernel_delta:
        print(f"{name:16s} tests~{total_tests:7d} min|z-tau|={min_margin:.3e} "
              f"max|dz|={max_delta:.3e} verdict-mismatches={verdict_mismatches}")
        return min_margin, max_delta, verdict_mismatches
    print(f"{name:16s} edges={edges_after:4d} levels={len(levels)} "
          f"tests~{total_tests:7d} min|z-tau|={min_margin:.3e}  per-level={levels}")
    return min_margin


GRID = [
    ("sparse-a01", 16, 200, ("er", 0.10), 0.01, None, 901, "pearson"),
    ("sparse-a05", 16, 200, ("er", 0.10), 0.05, None, 902, "pearson"),
    ("mid-lowm", 24, 150, ("er", 0.15), 0.01, None, 903, "pearson"),
    ("mid-highm", 24, 600, ("er", 0.15), 0.01, None, 904, "pearson"),
    ("dense-cap2", 24, 300, ("er", 0.30), 0.01, 2, 905, "pearson"),
    ("dense-a05-cap2", 24, 300, ("er", 0.30), 0.05, 2, 906, "pearson"),
    ("wide-lowm", 32, 120, ("er", 0.08), 0.01, None, 907, "pearson"),
    ("wide-cap1", 32, 400, ("er", 0.12), 0.01, 1, 908, "pearson"),
    ("dense-cap3", 20, 500, ("er", 0.35), 0.01, 3, 909, "pearson"),
    # PR 3 grid growth: GRN topologies + Spearman (Rank-PC) inputs
    ("grn-mid", 24, 300, ("grn", 1.8, 5), 0.01, None, 910, "pearson"),
    ("grn-a05-cap2", 28, 250, ("grn", 2.2, 6), 0.05, 2, 911, "pearson"),
    ("rank-er", 20, 300, ("er", 0.15), 0.01, None, 912, "spearman"),
    ("rank-grn", 24, 400, ("grn", 1.5, 5), 0.01, 2, 913, "spearman"),
]

def main_kernel_delta(eps):
    """Kernel numerics contract check (docs/NUMERICS.md): measure the
    reassociation delta on every grid test and assert it cannot flip any
    verdict. Exits nonzero on a verdict mismatch or a bound violation."""
    worst_margin = float("inf")
    worst_delta = 0.0
    mismatches = 0
    for row in GRID:
        margin, delta, bad = run_scenario(*row, kernel_delta=True)
        worst_margin = min(worst_margin, margin)
        worst_delta = max(worst_delta, delta)
        mismatches += bad
    print(f"\nworst margin over the grid:        {worst_margin:.3e}")
    print(f"worst reassociation |dz| observed: {worst_delta:.3e}")
    print(f"verdict mismatches:                {mismatches}")
    print("note: the shipped `blocked` kernel preserves scalar operation order "
          "per lane, so its delta is exactly 0; the bound above is the budget "
          "for future reassociating kernels.")
    ok = mismatches == 0 and worst_delta < worst_margin
    if eps is not None:
        print(f"requested kernel bound EPS={eps:.3e}: "
              + ("VERDICT-SAFE (EPS < worst margin)" if eps < worst_margin
                 else "UNSAFE (EPS >= worst margin — could flip a verdict)"))
        ok = ok and eps < worst_margin
    print("KERNEL CONTRACT HOLDS" if ok else "KERNEL CONTRACT VIOLATED")
    return 0 if ok else 1


if __name__ == "__main__":
    if "--kernel-delta" in sys.argv:
        idx = sys.argv.index("--kernel-delta")
        eps_arg = None
        if idx + 1 < len(sys.argv):
            eps_arg = float(sys.argv[idx + 1])
        sys.exit(main_kernel_delta(eps_arg))
    worst = float("inf")
    for row in GRID:
        worst = min(worst, run_scenario(*row))
    print(f"\nworst margin over the grid: {worst:.3e}")
    print("SAFE for f32 packing" if worst > 1e-5 else "TOO TIGHT — change seeds!")
