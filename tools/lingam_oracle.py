#!/usr/bin/env python3
"""Margin oracle for the ParaLiNGAM (DirectLiNGAM) engine family.

Replicates, draw for draw, the Rust side of the lingam grid:
rust/src/util/rng.rs (PCG-XSH-RR 64/32 + Box-Muller), sim/dag.rs
generators, the sim/sem.rs non-Gaussian noise kinds added for the
`lingam/` family (uniform and Laplace, both unit variance), and then
runs the exact pairwise-LR DirectLiNGAM procedure `rust/src/lingam/`
implements: standardize -> root-finding rounds over the maximum-entropy
measure -> causal order -> OLS pruning at |b| > PRUNE_THRESHOLD.

For every grid point it reports the *decision margins*:

* per round, the gap between the chosen root's score and the runner-up
  (the root decision margin). Scores are sums of min(0, D)^2 terms with
  |D| <= ~1e-3, so a summation-order delta of ~1e-13 on D moves a score
  by ~1e-15 at most; the 1e-9 gap floor leaves ~6 orders of magnitude
  of headroom for any faithful reimplementation;
* over the pruning regressions, min |b| - thr over kept edges and
  min thr - |b| over dropped candidates (floor 0.01 — near-threshold
  coefficients would make the point a coin flip, so seeds are chosen
  to keep every coefficient far from the gate);
* whether the recovered DAG equals the ground-truth DAG — the Rust
  conformance tests assert exactly that, so a grid point only ships if
  exact-arithmetic DirectLiNGAM provably recovers the truth on it.

`--scan LO HI` sweeps seeds for a candidate point definition (used once,
offline, to pick the shipped seeds); the bare invocation gates the
pinned LINGAM_GRID and exits nonzero if any margin dips under its floor.
"""
import math
import sys

import numpy as np

from margin_oracle import Pcg, random_er, random_grn

# rust: std::f64::consts::FRAC_1_SQRT_2 (correctly rounded 1/sqrt(2) —
# NOT python's 1/math.sqrt(2), which is one ulp low)
FRAC_1_SQRT_2 = 0.7071067811865476

# lingam/measure.rs constants (Hyvarinen 1998 maximum-entropy
# approximation, the same values the reference DirectLiNGAM uses)
K1 = 79.047
K2 = 7.4129
GAMMA = 0.37457
H_NU = (1.0 + math.log(2.0 * math.pi)) / 2.0

PRUNE_THRESHOLD = 0.05

ROOT_GAP_FLOOR = 1e-9
PRUNE_MARGIN_FLOOR = 0.01


def draw_noise(kind, rng):
    """Mirror of sim/sem.rs::NoiseKind::draw."""
    if kind == "gaussian":
        return rng.normal()
    if kind == "uniform":
        s = math.sqrt(3.0)
        return rng.uniform_in(-s, s)
    if kind == "laplace":
        while True:
            u = rng.uniform()
            if u == 0.0:
                continue
            if u < 0.5:
                x = math.log(2.0 * u)
            else:
                x = -math.log(2.0 * (1.0 - u))
            return x * FRAC_1_SQRT_2
    raise ValueError(kind)


def sem_sample(parents, n, m, rng, noise):
    x = np.zeros((m, n))
    for s in range(m):
        row = x[s]
        for i in range(n):
            v = draw_noise(noise, rng)
            for j, w in parents[i]:
                v += w * row[j]
            row[i] = v
    return x


def standardize(col):
    m = len(col)
    mean = col.sum() / m
    centered = col - mean
    var = (centered * centered).sum() / m
    sd = math.sqrt(var)
    if sd <= 1e-12:
        return np.zeros_like(col)
    return centered / sd


def entropy(u):
    """H-hat(u) for an (approximately) standardized sample u."""
    m = len(u)
    lc = np.log(np.cosh(u)).sum() / m
    ue = (u * np.exp(-(u * u) / 2.0)).sum() / m
    return H_NU - K1 * (lc - GAMMA) ** 2 - K2 * ue * ue


def measure(xi, xj):
    """D(i,j): > 0 iff i is the more plausible cause (lingam/measure.rs)."""
    m = len(xi)
    c = (xi * xj).sum() / m
    s2 = max(1.0 - c * c, 1e-12)
    s = math.sqrt(s2)
    ri_j = (xi - c * xj) / s
    rj_i = (xj - c * xi) / s
    return (entropy(xj) + entropy(ri_j)) - (entropy(xi) + entropy(rj_i))


def causal_order(x_std, n):
    """Root-finding rounds; returns (order, per-round root gaps)."""
    cols = [x_std[:, v].copy() for v in range(n)]
    active = list(range(n))
    order = []
    gaps = []
    while len(active) > 1:
        k = len(active)
        scores = [0.0] * k
        for ai in range(k):
            for bi in range(ai + 1, k):
                d = measure(cols[active[ai]], cols[active[bi]])
                scores[ai] += min(0.0, d) ** 2
                scores[bi] += min(0.0, -d) ** 2
        best = min(range(k), key=lambda i: (scores[i], i))
        ranked = sorted(scores)
        gaps.append(ranked[1] - ranked[0])
        root = active[best]
        order.append(root)
        m = len(cols[root])
        for v in active:
            if v == root:
                continue
            c = (cols[v] * cols[root]).sum() / m
            cols[v] = standardize(cols[v] - c * cols[root])
        active.pop(best)
    order.append(active[0])
    return order, gaps


def prune(x_std, order):
    """OLS of each var on its causal-order predecessors (original
    standardized data), keep |b| > PRUNE_THRESHOLD. Returns (edges,
    min kept margin, min dropped margin)."""
    m = x_std.shape[0]
    edges = []
    kept_margin = float("inf")
    dropped_margin = float("inf")
    for p in range(1, len(order)):
        child = order[p]
        preds = order[:p]
        xp = x_std[:, preds]
        a = (xp.T @ xp) / m
        b = (xp.T @ x_std[:, child]) / m
        w = np.linalg.solve(a, b)
        for q, parent in enumerate(preds):
            coef = abs(w[q])
            if coef > PRUNE_THRESHOLD:
                kept_margin = min(kept_margin, coef - PRUNE_THRESHOLD)
                edges.append((parent, child, float(w[q])))
            else:
                dropped_margin = min(dropped_margin, PRUNE_THRESHOLD - coef)
    return edges, kept_margin, dropped_margin


def truth_edges(parents):
    out = set()
    for child, ps in enumerate(parents):
        for j, _w in ps:
            out.add((j, child))
    return out


def run_point(name, n, m, topology, seed, noise, verbose=True):
    if topology[0] == "er":
        parents = random_er(n, topology[1], Pcg(seed, 1))
    else:
        parents = random_grn(n, topology[1], topology[2], Pcg(seed, 1))
    x = sem_sample(parents, n, m, Pcg(seed, 2), noise)
    x_std = np.column_stack([standardize(x[:, v]) for v in range(n)])
    order, gaps = causal_order(x_std, n)
    edges, kept, dropped = prune(x_std, order)
    got = {(a, b) for (a, b, _w) in edges}
    want = truth_edges(parents)
    exact = got == want
    min_gap = min(gaps) if gaps else float("inf")
    ok = exact and min_gap >= ROOT_GAP_FLOOR \
        and kept >= PRUNE_MARGIN_FLOOR and dropped >= PRUNE_MARGIN_FLOOR
    if verbose:
        print(f"{name:16s} n={n:3d} m={m:5d} noise={noise:8s} "
              f"edges={len(want):3d} order={order}")
        print(f"{'':16s} root-gap(min)={min_gap:.3e} "
              f"prune kept={kept:.4f} dropped={dropped:.4f} "
              f"truth={'EXACT' if exact else 'MISMATCH ' + str(sorted(got ^ want))}"
              f" -> {'OK' if ok else 'BAD'}")
    return ok, min_gap, kept, dropped, exact


# The pinned lingam grid — must stay in lockstep with
# rust/src/sim/scenarios.rs::lingam_grid (name, n, m, topology, seed,
# noise). Seeds chosen by `--scan` so every decision clears its floor.
LINGAM_GRID = [
    ("lingam-uniform", 12, 5000, ("er", 0.2), 918, "uniform"),
    ("lingam-laplace", 10, 5000, ("er", 0.25), 916, "laplace"),
    ("lingam-grn", 14, 4000, ("grn", 1.8, 4), 953, "uniform"),
]


def scan(lo, hi):
    for (name, n, m, topo, _seed, noise) in LINGAM_GRID:
        print(f"== scanning {name} ==")
        for seed in range(lo, hi):
            ok, gap, kept, dropped, exact = run_point(
                name, n, m, topo, seed, noise, verbose=False)
            flag = "OK " if ok else "   "
            print(f"  seed {seed}: {flag} gap={gap:.2e} kept={kept:.4f} "
                  f"dropped={dropped:.4f} exact={exact}")


if __name__ == "__main__":
    if "--scan" in sys.argv:
        i = sys.argv.index("--scan")
        scan(int(sys.argv[i + 1]), int(sys.argv[i + 2]))
        sys.exit(0)
    all_ok = True
    for row in LINGAM_GRID:
        ok, *_ = run_point(*row)
        all_ok = all_ok and ok
    print("\nLINGAM GRID SAFE" if all_ok else "\nLINGAM GRID UNSAFE — change seeds!")
    sys.exit(0 if all_ok else 1)
