#!/usr/bin/env python3
"""Numerically validate the new chol.rs property tests by mirroring
pinv/pinv_fast (f64, operation-for-operation) and the Pcg streams."""
import math
from margin_oracle import Pcg

CHOL_EPS = 1e-8


def cholesky(a, l, rank_tol):
    out = [0.0] * (l * l)
    for k in range(l):
        s = a[k * l + k]
        for m in range(k):
            s -= out[k * l + m] ** 2
        if rank_tol > 0.0:
            if s > rank_tol:
                d = math.sqrt(max(s, CHOL_EPS))
                dkk, inv = d, 1.0 / d
            else:
                dkk, inv = 0.0, 0.0
        else:
            d = math.sqrt(max(s, CHOL_EPS))
            dkk, inv = d, 1.0 / d
        out[k * l + k] = dkk
        for i in range(k + 1, l):
            s = a[i * l + k]
            for m in range(k):
                s -= out[i * l + m] * out[k * l + m]
            out[i * l + k] = s * inv
    return out


def tril_inverse(lm, l):
    out = [0.0] * (l * l)
    for j in range(l):
        for i in range(j, l):
            s = 1.0 if i == j else 0.0
            for k in range(j, i):
                s -= lm[i * l + k] * out[k * l + j]
            d = lm[i * l + i]
            out[i * l + j] = s / d if d != 0.0 else 0.0
    return out


def matmul(a, b, l):
    out = [0.0] * (l * l)
    for i in range(l):
        for k in range(l):
            if a[i * l + k] == 0.0:
                continue
            for j in range(l):
                out[i * l + j] += a[i * l + k] * b[k * l + j]
    return out


def gram(a, l):
    out = [0.0] * (l * l)
    for k in range(l):
        for i in range(l):
            if a[k * l + i] == 0.0:
                continue
            for j in range(l):
                out[i * l + j] += a[k * l + i] * a[k * l + j]
    return out


def spd_inverse(a, l):
    lm = cholesky(a, l, 0.0)
    li = tril_inverse(lm, l)
    out = [0.0] * (l * l)
    for k in range(l):
        for i in range(l):
            if li[k * l + i] == 0.0:
                continue
            for j in range(l):
                out[i * l + j] += li[k * l + i] * li[k * l + j]
    return out


def pinv(m2, l):
    if l == 1:
        x = m2[0]
        return [x / (x * x + CHOL_EPS)]
    mtm = gram(m2, l)
    maxd = max(mtm[d * l + d] for d in range(l))
    rank_tol = maxd * 1e-6 + CHOL_EPS
    lm = cholesky(mtm, l, rank_tol)
    ltl = gram(lm, l)
    for d in range(l):
        ltl[d * l + d] += CHOL_EPS
    r = spd_inverse(ltl, l)
    t1 = matmul(lm, r, l)
    t2 = matmul(t1, r, l)
    t1 = [0.0] * (l * l)
    for i in range(l):
        for k in range(l):
            v = t2[i * l + k]
            if v == 0.0:
                continue
            for j in range(l):
                t1[i * l + j] += v * lm[j * l + k]
    out = [0.0] * (l * l)
    for i in range(l):
        for k in range(l):
            v = t1[i * l + k]
            if v == 0.0:
                continue
            for j in range(l):
                out[i * l + j] += v * m2[j * l + k]
    return out


def pinv_fast(m2, l):
    DET_TOL = 1e-6
    if l == 1:
        x = m2[0]
        return [x / (x * x + CHOL_EPS)]
    if l == 2:
        a, b, c, d = m2
        det = a * d - b * c
        scale = max(abs(a), abs(b), abs(c), abs(d))
        if abs(det) > DET_TOL * scale * scale:
            inv = 1.0 / det
            return [d * inv, -b * inv, -c * inv, a * inv]
        return pinv(m2, l)
    if l == 3:
        m = m2
        c00 = m[4] * m[8] - m[5] * m[7]
        c01 = m[5] * m[6] - m[3] * m[8]
        c02 = m[3] * m[7] - m[4] * m[6]
        det = m[0] * c00 + m[1] * c01 + m[2] * c02
        scale = max(abs(x) for x in m)
        if abs(det) > DET_TOL * scale ** 3:
            inv = 1.0 / det
            return [
                c00 * inv, (m[2] * m[7] - m[1] * m[8]) * inv, (m[1] * m[5] - m[2] * m[4]) * inv,
                c01 * inv, (m[0] * m[8] - m[2] * m[6]) * inv, (m[2] * m[3] - m[0] * m[5]) * inv,
                c02 * inv, (m[1] * m[6] - m[0] * m[7]) * inv, (m[0] * m[4] - m[1] * m[3]) * inv,
            ]
        return pinv(m2, l)
    maxd = max(m2[d * l + d] for d in range(l))
    rank_tol = maxd * 1e-6 + CHOL_EPS
    lm = cholesky(m2, l, rank_tol)
    if all(lm[d * l + d] > 0.0 for d in range(l)):
        t1 = tril_inverse(lm, l)
        out = [0.0] * (l * l)
        for k in range(l):
            for i in range(l):
                v = t1[k * l + i]
                if v == 0.0:
                    continue
                for j in range(i + 1):
                    out[i * l + j] += v * t1[k * l + j]
        for i in range(l):
            for j in range(i + 1, l):
                out[i * l + j] = out[j * l + i]
        return out
    return pinv(m2, l)


def random_spd(rng, l):
    b = [rng.normal() for _ in range(l * l)]
    a = [0.0] * (l * l)
    for i in range(l):
        for j in range(l):
            s = 0.1 if i == j else 0.0
            for k in range(l):
                s += b[i * l + k] * b[j * l + k]
            a[i * l + j] = s
    return a


def gauss_jordan(a, l):
    import numpy as np
    try:
        return list(np.linalg.inv(np.array(a).reshape(l, l)).ravel())
    except np.linalg.LinAlgError:
        return None


def identity_residual(a, x, l):
    worst = 0.0
    for i in range(l):
        for j in range(l):
            acc = sum(a[i * l + k] * x[k * l + j] for k in range(l))
            worst = max(worst, abs(acc - (1.0 if i == j else 0.0)))
    return worst


# --- test 1: property sweep ---
rng = Pcg(31, 54)
worst_resid, worst_rel = 0.0, 0.0
for l in range(1, 13):
    for rep in range(10):
        a = random_spd(rng, l)
        fast = pinv_fast(a, l)
        resid = identity_residual(a, fast, l)
        gj = gauss_jordan(a, l)
        scale = max([1.0] + [abs(x) for x in gj])
        rel = max(abs(f - g) for f, g in zip(fast, gj)) / scale
        worst_resid = max(worst_resid, resid)
        worst_rel = max(worst_rel, rel)
print(f"sweep: worst |A·Ainv−I| = {worst_resid:.3e} (tol 1e-4), "
      f"worst rel GJ diff = {worst_rel:.3e} (tol 1e-4)")
assert worst_resid < 1e-4 and worst_rel < 1e-4, "SWEEP WOULD FAIL"

# --- test 2: near-singular ---
rng = Pcg(32, 54)
worst_pen = 0.0
for l in range(2, 9):
    r = l - 1
    b = [rng.normal() for _ in range(l * r)]
    a = [0.0] * (l * l)
    for i in range(l):
        for j in range(l):
            s = 1e-10 if i == j else 0.0
            for k in range(r):
                s += b[i * r + k] * b[j * r + k]
            a[i * l + j] = s
    p = pinv_fast(a, l)
    assert all(math.isfinite(v) for v in p), f"l={l} non-finite"
    ap = matmul(a, p, l)
    apa = matmul(ap, a, l)
    scale = max([1e-12] + [abs(x) for x in a])
    diff = max(abs(x - y) for x, y in zip(apa, a)) / scale
    worst_pen = max(worst_pen, diff)
    print(f"near-singular l={l}: penrose rel diff = {diff:.3e} (tol 1e-3)")
assert worst_pen < 1e-3, "NEAR-SINGULAR WOULD FAIL"
print("both chol tests PASS numerically")
