#!/usr/bin/env python3
"""Schedule oracle for the reversed-order pruning family (PR 7).

f64 mirror of the two batched edge schedules over the dense conformance
grid points, predicting the exact per-level CI-test counts the Rust
engines must report (safe because margin_oracle shows min |z - tau| >>
f32 rounding over the whole grid, so the f64 mirror reaches the same
independence decisions as the f32 packed kernels):

* cuPC-E (gamma = 32, the paper-selected config `sc.config()` uses):
  per directed live edge, windows of gamma combination indices in
  ascending order, removals applied at round end;
* reversed-order pruning (arxiv 2109.04626 adapted to PC-stable's
  level-synchronous frame): flight size 1, combination indices walked in
  DESCENDING order, densest rows first, removals applied at round end.

Both must produce the identical skeleton (PC-stable order-independence);
the reversed schedule must spend strictly fewer total tests on at least
one dense point — the conformance gate
`tests/conformance_engines.rs::reversed_order_spends_fewer_tests_than_cupc_e`
asserts exactly what this oracle prints.
"""
import math
from itertools import combinations

from margin_oracle import Pcg, random_er, sem_sample, correlation, phi_inv, \
    fisher_z, partial_corr

GAMMA = 32  # Config::default().gamma — sc.config() keeps it


def gen_point(n, m, d, seed):
    parents = random_er(n, d, Pcg(seed, 1))
    x = sem_sample(parents, n, m, Pcg(seed, 2))
    return correlation(x)


def level0(c, n, m, alpha):
    """Exhaustive pair sweep shared by every schedule."""
    adj = [[i != j for j in range(n)] for i in range(n)]
    tau0 = phi_inv(1.0 - alpha / 2.0) / math.sqrt(m - 3)
    tests = n * (n - 1) // 2
    for i in range(n):
        for j in range(i + 1, n):
            if fisher_z(c[i, j]) <= tau0:
                adj[i][j] = adj[j][i] = False
    return adj, tests


def max_degree(adj):
    return max(sum(r) for r in adj)


def should_continue(adj, l, cap):
    if cap is not None and l > cap:
        return False
    return max_degree(adj) > l


def edge_tasks(adj, n, l):
    """Directed live edges with enough neighbors: (i, j, p, row, total)."""
    tasks = []
    for i in range(n):
        row = [j for j in range(n) if adj[i][j]]
        nr = len(row)
        if nr < l + 1:
            continue
        total = math.comb(nr - 1, l)
        if total == 0:
            continue
        for p, j in enumerate(row):
            tasks.append((i, j, p, row, total))
    return tasks


def run_schedule(c, n, m, alpha, cap, reversed_order):
    """One batched edge schedule; returns (adj, total_tests, per_level)."""
    adj, tests0 = level0(c, n, m, alpha)
    total_tests = tests0
    per_level = [tests0]
    flight = 1 if reversed_order else GAMMA
    l = 1
    while should_continue(adj, l, cap):
        dof = m - l - 3
        taul = phi_inv(1.0 - alpha / 2.0) / math.sqrt(dof)
        tasks = edge_tasks(adj, n, l)
        if reversed_order:
            # densest-first, stable (ties keep row-major construction order)
            tasks.sort(key=lambda t: -len(t[3]))
        ltests = 0
        rnd = 0
        max_total = max((t[4] for t in tasks), default=0)
        while rnd * flight < max_total:
            removals = []
            any_run = False
            for (i, j, p, row, total) in tasks:
                if rnd * flight >= total:
                    continue
                if not adj[i][j]:
                    continue
                any_run = True
                if reversed_order:
                    window = [total - 1 - rnd]   # descending, one in flight
                else:
                    lo = rnd * flight
                    window = range(lo, min(lo + flight, total))
                pool = [x for x in range(len(row)) if x != p]
                for t in window:
                    ltests += 1
                    s_pos = list(combinations(pool, l))[t]
                    s = [row[x] for x in s_pos]
                    if fisher_z(partial_corr(c, i, j, s)) <= taul:
                        removals.append((min(i, j), max(i, j)))
            if not any_run:
                break
            for (a, b) in removals:
                adj[a][b] = adj[b][a] = False
            rnd += 1
        total_tests += ltests
        per_level.append(ltests)
        l += 1
    return adj, total_tests, per_level


DENSE = [
    ("dense-cap2", 24, 300, 0.30, 0.01, 2, 905),
    ("dense-a05-cap2", 24, 300, 0.30, 0.05, 2, 906),
    ("dense-cap3", 20, 500, 0.35, 0.01, 3, 909),
]

if __name__ == "__main__":
    fewer = 0
    for (name, n, m, d, alpha, cap, seed) in DENSE:
        c = gen_point(n, m, d, seed)
        adj_e, te, lv_e = run_schedule(c, n, m, alpha, cap, reversed_order=False)
        adj_r, tr, lv_r = run_schedule(c, n, m, alpha, cap, reversed_order=True)
        assert adj_e == adj_r, f"{name}: schedules disagree on the skeleton"
        edges = sum(sum(r) for r in adj_e) // 2
        mark = "REVERSED FEWER" if tr < te else "no saving"
        if tr < te:
            fewer += 1
        print(f"{name:16s} edges={edges:3d}  cupc-e(g=32)={te:6d} {lv_e}  "
              f"reversed={tr:6d} {lv_r}  -> {mark}")
    print(f"\nreversed strictly fewer on {fewer}/{len(DENSE)} dense points")
    assert fewer >= 1, "the conformance gate's premise does not hold!"
    print("OK: gate premise holds")
